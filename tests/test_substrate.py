"""Tests: data pipeline, optimizer, checkpointing/restart, elastic restore,
gradient compression, straggler detection, fleet simulation."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import make_batch_fn
from repro.engine import (CompileCostModel, FaultInjector, FleetSim, MLTask,
                          StragglerMonitor, TrainSupervisor)
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.grad_compression import compress_grads, ef_init


# ------------------------------------------------------------------ data ---

def test_pipeline_deterministic_and_restart_safe():
    cfg = get_arch("llama3.2-3b").reduced()
    shape = ShapeConfig("t", 16, 8, "train")
    b1 = make_batch_fn(cfg, shape, seed=3)(5)
    b2 = make_batch_fn(cfg, shape, seed=3)(5)   # fresh pipeline, same step
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch_fn(cfg, shape, seed=4)(5)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_pipeline_microbatch_layout():
    cfg = get_arch("llama3.2-3b").reduced()
    shape = ShapeConfig("t", 16, 8, "train", microbatch=2)
    b = make_batch_fn(cfg, shape, 0)(0)
    assert b["tokens"].shape == (4, 2, 16)
    assert b["labels"].shape == (4, 2, 16)


def test_pipeline_labels_are_shifted_tokens():
    cfg = get_arch("llama3.2-3b").reduced()
    shape = ShapeConfig("t", 16, 4, "train")
    b = make_batch_fn(cfg, shape, 0)(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ----------------------------------------------------------------- adamw ---

def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10_000,
                      weight_decay=0.0, clip_norm=1e9)
    p = {"w": jnp.array([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.array([[0.1, 0.2], [-0.3, 0.4]])}
    st_ = adamw_init(p, cfg)
    newp, newst, stats = adamw_update(p, g, st_, cfg)
    # numpy reference
    pn, gn = np.asarray(p["w"]), np.asarray(g["w"])
    m = (1 - cfg.b1) * gn
    v = (1 - cfg.b2) * gn ** 2
    mhat = m / (1 - cfg.b1)
    vhat = v / (1 - cfg.b2)
    # cosine schedule at step 1 with no warmup
    prog = 1.0 / 10_000
    lr = cfg.lr * 0.5 * (1 + math.cos(math.pi * prog))
    want = pn - lr * mhat / (np.sqrt(vhat) + cfg.eps)
    np.testing.assert_allclose(np.asarray(newp["w"]), want, rtol=1e-5)
    assert int(newst["step"]) == 1


def test_adamw_clipping():
    cfg = AdamWConfig(clip_norm=0.001, warmup_steps=0, weight_decay=0.0)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st_ = adamw_init(p, cfg)
    _, _, stats = adamw_update(p, g, st_, cfg)
    assert float(stats["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


# ------------------------------------------------------------ checkpoint ---

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.array(3, jnp.int32),
                  "d": jnp.ones((4,), jnp.bfloat16)}}
    save(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    out = restore(tmp_path, 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, every=1)
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.maybe_save(s, tree)
    mgr.wait()
    assert mgr.latest() == 4
    assert latest_step(tmp_path) == 4
    import re
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if re.fullmatch(r"step_\d+", p.name))
    assert len(steps) <= 3          # keep=2 plus possibly one in-flight


def _tiny_trainer(tmp_path, fail_at=(), steps=12, every=4):
    cfg = get_arch("xlstm-125m").reduced()
    shape = ShapeConfig("t", 16, 4, "train")
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps)
    batch_fn = make_batch_fn(cfg, shape, 0)

    @jax.jit
    def train_step(state, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        newp, newo, stats = adamw_update(
            state["params"], grads,
            {"m": state["m"], "v": state["v"], "step": state["step"]}, opt)
        return {"params": newp, **newo}, {"loss": loss}

    def step_fn(state, i):
        return train_step(state, batch_fn(i))

    def make_state():
        params = model.init(jax.random.PRNGKey(0))
        o = adamw_init(params, opt)
        return {"params": params, **o}

    sup = TrainSupervisor(str(tmp_path), make_state, step_fn, every=every,
                          injector=FaultInjector(fail_at) if fail_at else None)
    state, log, restarts = sup.run(steps)
    return float(log[-1][1]["loss"]), restarts


def test_restart_is_equivalent_to_uninterrupted(tmp_path):
    """Fault at step 9 + restore from step 8 must reproduce the exact
    uninterrupted trajectory (stateless data + deterministic step)."""
    loss_plain, r0 = _tiny_trainer(tmp_path / "a")
    loss_fault, r1 = _tiny_trainer(tmp_path / "b", fail_at=(9,))
    assert r0 == 0 and r1 == 1
    assert loss_plain == pytest.approx(loss_fault, rel=1e-5)


def test_elastic_restore_with_different_sharding(tmp_path):
    """Restore applies any target sharding (elastic re-mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8.0)}
    save(tmp_path, 1, tree)
    sh = {"w": NamedSharding(mesh, P("data"))}
    out = restore(tmp_path, 1, tree, shardings=sh)
    assert out["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))


# ------------------------------------------------------ grad compression ---

@given(scale=st.floats(0.01, 100.0), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_compression_error_bounded(scale, seed):
    g = {"w": scale * jax.random.normal(jax.random.PRNGKey(seed), (64,))}
    e = ef_init(g)
    d, new_e = compress_grads(g, e)
    # per-element error bounded by quantization step (max|x| / 127 / 2 + eps)
    bound = float(jnp.max(jnp.abs(g["w"]))) / 127.0 * 0.51 + 1e-9
    assert float(jnp.max(jnp.abs(new_e["w"]))) <= bound


def test_compression_error_feedback_preserves_sum():
    """EF invariant: dequantized + residual == original + previous residual."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (128,))}
    e = ef_init(g)
    d, new_e = compress_grads(g, e)
    np.testing.assert_allclose(np.asarray(d["w"] + new_e["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


# -------------------------------------------------------------- engine -----

def test_straggler_monitor():
    mon = StragglerMonitor(factor=1.5, min_samples=2)
    for _ in range(5):
        mon.record("fast1", 1.0)
        mon.record("fast2", 1.1)
        mon.record("slow", 3.0)
    assert mon.stragglers() == ["slow"]


def test_fleet_sim_pools_beat_jobs():
    """The paper's result at ML-fleet scale: per-task dispatch pays compile
    latency per task; pools amortize it."""
    fleet = FleetSim(n_slices=8, cost=CompileCostModel(art_dir="/nonexist"))
    tasks = [MLTask("llama3.2-3b", "decode_32k", steps=40)
             for _ in range(60)]
    tasks += [MLTask("mixtral-8x7b", "prefill_32k", steps=10)
              for _ in range(40)]
    wf_a = fleet.workload(tasks)
    wf_b = fleet.workload(tasks)
    rep_job = fleet.run(wf_a, model="job", compile_overhead=30.0)
    rep_pool = fleet.run(wf_b, model="worker_pools", compile_overhead=30.0)
    assert rep_pool.makespan < rep_job.makespan
    assert rep_pool.pods_created < rep_job.pods_created
    assert rep_pool.utilization > rep_job.utilization


def test_fleet_sim_mixed_train_serve_proportional():
    """Intertwined train chains + serving bursts both make progress."""
    fleet = FleetSim(n_slices=8, cost=CompileCostModel(art_dir="/nonexist"))
    chain = [MLTask("llama3.2-3b", "train_4k", steps=100) for _ in range(6)]
    serve = [MLTask("granite-moe-1b-a400m", "decode_32k", steps=50)
             for _ in range(30)]
    wf = fleet.workload(serve, chains=[chain])
    rep = fleet.run(wf, model="worker_pools", compile_overhead=20.0)
    assert rep.makespan > 0
    types = {t.type for t in wf.tasks.values()}
    assert len(types) == 2
    # every task completed despite competition
    assert all(t.done for t in wf.tasks.values())
