"""Correctness of the serving optimizations: sequence-parallel decode
(LSE combine math + shard_map path) and int8 KV caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.kernels import ref
from repro.launch.mesh import make_mesh
from repro.models import build_model
from repro.models.attention import _flash_fwd_impl, _pad_to
from repro.parallel.policies import policy_for
from repro.parallel.sharding import use_policy


def test_lse_combine_matches_full_attention():
    """The cross-shard combine used by seq_sharded_decode: split KV into
    chunks, compute per-chunk flash partials, LSE-combine -> must equal
    attention over the full KV."""
    B, S, K, G, H = 2, 256, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, K, G, H)) * 0.5
    k = jax.random.normal(ks[1], (B, S, K, H)) * 0.5
    v = jax.random.normal(ks[2], (B, S, K, H)) * 0.5
    pos = 200                       # only first 201 slots valid
    want = ref.decode_ref(q, k, v, pos + 1)

    n_sh, S_loc = 4, S // 4
    outs, lses = [], []
    qp, _ = _pad_to(q, 1, 16)
    for r in range(n_sh):
        k_l = k[:, r * S_loc:(r + 1) * S_loc]
        v_l = v[:, r * S_loc:(r + 1) * S_loc]
        local_valid = np.clip(pos + 1 - r * S_loc, 0, S_loc)
        o, lse = _flash_fwd_impl(qp, k_l, v_l, False, 0,
                                 jnp.int32(local_valid), 0, 16,
                                 min(64, S_loc))
        outs.append(np.asarray(o[:, :1], np.float32))
        lses.append(np.asarray(lse[:, 0, :, :, 0][:, None]))  # (B,1,K,G)
    lses = np.stack(lses)                         # (n_sh,B,1,K,G)
    m = lses.max(0)
    w = np.exp(lses - m)
    den = w.sum(0)
    num = sum(o * w[i][..., None] for i, o in enumerate(outs))
    got = num / den[..., None]
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("cache_seq_rule", [None, "model"])
def test_seq_sharded_decode_path_matches(cache_seq_rule):
    """decode_step through the shard_map path (1-device mesh, trivial
    sharding) must match the plain path."""
    cfg = get_arch("llama3.2-3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = 2, 24
    mesh = make_mesh((1, 1), ("data", "model"))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)

    def run(rule):
        cache = model.init_cache(B, P + 8, dtype=jnp.float32)
        pol = policy_for(cfg, mesh, overrides={"cache_seq": rule} if rule
                         else None, global_batch=B)
        with use_policy(pol):
            logits, cache = jax.jit(model.prefill)(
                params, {"tokens": toks}, cache)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            d_logits, cache = jax.jit(model.decode_step)(
                params, nxt, cache, jnp.int32(P))
        return np.asarray(d_logits, np.float32)

    base = run(None)
    got = run(cache_seq_rule)
    np.testing.assert_allclose(got, base, rtol=2e-2, atol=2e-2)


def test_int8_kv_cache_close_to_fp32():
    cfg = get_arch("llama3.2-3b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                              cfg.vocab_size)

    def run(dtype):
        cache = model.init_cache(B, P + 8, dtype=dtype)
        logits, cache = jax.jit(model.prefill)(params, {"tokens": toks},
                                               cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        d_logits, _ = jax.jit(model.decode_step)(params, nxt, cache,
                                                 jnp.int32(P))
        return np.asarray(d_logits, np.float32)

    f32 = run(jnp.float32)
    q8 = run(jnp.int8)
    assert np.all(np.isfinite(q8))
    # quantized cache: same top-1 prediction for most positions, logits close
    agree = (q8.argmax(-1) == f32.argmax(-1)).mean()
    assert agree >= 0.5, f"top-1 agreement {agree}"
    np.testing.assert_allclose(q8, f32, rtol=0.35, atol=0.6)
