"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs; plus prefill+decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import build_model

B, S = 2, 16


def make_batch(cfg, rng):
    r1, r2 = jax.random.split(rng)
    batch = {
        "tokens": jax.random.randint(r1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(r2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            r1, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            r1, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_loss(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_grad_step(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(g)))
        return l, gn

    loss, gn = step(params)
    assert np.isfinite(float(loss)) and np.isfinite(float(gn))
    assert float(gn) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_forward(arch):
    """Prefill(S tokens) then decode token-by-token must match the parallel
    forward's next-token logits."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    logits_all, _, _ = jax.jit(
        lambda p, b: __import__("repro.models.model", fromlist=["forward"])
        .forward(p, b, cfg, kind="train"))(params, batch)

    cache = model.init_cache(B, max_len=S + 4, dtype=jnp.float32)
    pre_batch = {k: v for k, v in batch.items() if k != "labels"}
    last, cache = jax.jit(model.prefill)(params, pre_batch, cache)
    np.testing.assert_allclose(
        np.asarray(last[:, 0], np.float32),
        np.asarray(logits_all[:, -1], np.float32), rtol=2e-2, atol=2e-2)

    # one decode step
    nxt = jnp.argmax(last, -1).astype(jnp.int32)
    dec_logits, cache = jax.jit(model.decode_step)(
        params, nxt, cache, jnp.int32(S))
    assert dec_logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(dec_logits, np.float32)))
