"""Unit + property tests for the paper core: workflow DAG, cluster simulator,
execution models, proportional autoscaler."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ClusterSim, ClusteredExecutor, HyperflowEngine,
                        JobExecutor, WorkerPoolExecutor, Workflow, montage,
                        proportional_replicas)
from repro.core import experiment as ex


# --------------------------------------------------------------- workflow --

def test_workflow_dag_bookkeeping():
    wf = Workflow()
    a = wf.add("A", 1.0)
    b = wf.add("B", 2.0, deps=(a,))
    c = wf.add("C", 3.0, deps=(a, b))
    assert [t.id for t in wf.roots()] == [a]
    ready = wf.complete(a, 1.0)
    assert [t.id for t in ready] == [b]
    ready = wf.complete(b, 3.0)
    assert [t.id for t in ready] == [c]
    assert not wf.all_done()
    wf.complete(c, 6.0)
    assert wf.all_done()
    assert wf.critical_path() == pytest.approx(6.0)
    assert wf.total_work() == pytest.approx(6.0)


def test_montage_structure():
    wf = montage(n_tiles=100, seed=1)
    types = wf.task_types()
    assert types["mProject"] == 100
    assert types["mDiffFit"] == int(100 * 2.9375)
    assert types["mBackground"] == 100
    for single in ("mConcatFit", "mBgModel", "mImgtbl", "mAdd", "mShrink",
                   "mJPEG"):
        assert types[single] == 1
    # 16k-task canonical instance
    wf16 = ex.make_workflow()
    assert 15_500 <= len(wf16) <= 16_500


# ------------------------------------------------------------- autoscaler --

@given(
    demand=st.dictionaries(st.sampled_from(list("abcdef")),
                           st.integers(0, 10_000), min_size=1),
    quota=st.floats(1.0, 500.0),
)
@settings(max_examples=200, deadline=None)
def test_proportional_replicas_invariants(demand, quota):
    cpu = {p: 1.0 for p in demand}
    repl = proportional_replicas(demand, cpu, quota)
    assert set(repl) == set(demand)
    for p in demand:
        assert repl[p] >= 0
        assert repl[p] <= math.ceil(demand[p])          # never over-provision
    total_demand = sum(demand.values())
    if total_demand > quota:
        assert sum(repl.values()) <= quota + 1e-9       # quota respected
    # scale-to-zero
    for p in demand:
        if demand[p] == 0:
            assert repl[p] == 0


@given(
    d1=st.integers(1, 10_000), d2=st.integers(1, 10_000),
)
@settings(max_examples=100, deadline=None)
def test_proportional_replicas_proportionality(d1, d2):
    quota = 64.0
    repl = proportional_replicas({"a": d1, "b": d2}, {"a": 1.0, "b": 1.0},
                                 quota)
    if d1 + d2 > quota:
        # allocation tracks the demand ratio within rounding of one replica
        share_a = quota * d1 / (d1 + d2)
        assert abs(repl["a"] - share_a) <= 1.0 + 1e-9
        # quota fully used when both pools can absorb it
        if repl["a"] < d1 and repl["b"] < d2:
            assert sum(repl.values()) >= quota - 1.0


def test_proportional_replicas_cpu_weights():
    # allocation is proportional to core-demand (tasks x cpu): pool b's
    # demand is 2x in core terms, so it receives 2x the cores
    repl = proportional_replicas({"a": 100, "b": 100}, {"a": 1.0, "b": 2.0},
                                 60.0)
    assert repl["b"] * 2.0 == pytest.approx(2 * repl["a"] * 1.0, abs=4.0)
    assert repl["a"] * 1.0 + repl["b"] * 2.0 <= 60.0 + 1e-9


# ------------------------------------------------------------- simulator ---

def _run(model: str, n_tiles=60, seed=3):
    rep, wf, sim = ex.run_model(model, seed=seed, n_tiles=n_tiles)
    return rep, wf, sim


@pytest.mark.parametrize("model", ["job", "clustered", "worker_pools"])
def test_no_task_starts_before_deps(model):
    rep, wf, sim = _run(model)
    assert wf.all_done()
    for t in wf.tasks.values():
        for d in t.deps:
            dep = wf.tasks[d]
            assert dep.finished_at <= t.started_at + 1e-9, \
                f"{t.type} started before dep {dep.type}"


@pytest.mark.parametrize("model", ["job", "clustered", "worker_pools"])
def test_capacity_never_exceeded(model):
    rep, wf, sim = _run(model)
    cap = sim.capacity_cores()
    assert all(v <= cap + 1e-9 for _, v in sim.busy_cores_trace)
    for node in sim.nodes:
        assert node.used_cpu <= node.cpu + 1e-9
        assert node.used_cpu >= -1e-9


@pytest.mark.parametrize("model", ["job", "clustered", "worker_pools"])
def test_makespan_lower_bounds(model):
    rep, wf, sim = _run(model)
    assert rep.makespan >= wf.critical_path() - 1e-9
    assert rep.makespan >= wf.total_work() / sim.capacity_cores() - 1e-9


def test_model_ordering_and_pod_counts():
    """The paper's qualitative result on a mid-size instance: pools beat
    clustering beats jobs, and pools create far fewer pods."""
    reps = {m: _run(m, n_tiles=400, seed=5)[0]
            for m in ("job", "clustered", "worker_pools")}
    assert reps["worker_pools"].makespan < reps["clustered"].makespan
    assert reps["clustered"].makespan < reps["job"].makespan
    # both mitigations create far fewer pods than one-pod-per-task
    assert reps["worker_pools"].pods_created < reps["job"].pods_created / 3
    assert reps["clustered"].pods_created < reps["job"].pods_created / 3
    assert reps["worker_pools"].utilization > reps["job"].utilization


def test_clustering_batches_bounded():
    """No clustered pod may run more than `size` tasks."""
    wf = ex.make_workflow(seed=3, n_tiles=60)
    sim = ex.make_sim(seed=3)
    execu = ClusteredExecutor(ex.CLUSTERING_RULES)
    HyperflowEngine(wf, execu, sim).run()
    # pods_created >= tasks / max_size
    n = len(wf)
    max_size = max(r["size"] for r in ex.CLUSTERING_RULES.values())
    assert sim.pods_created >= n / max_size


def test_worker_pools_scale_to_zero():
    rep, wf, sim = _run("worker_pools")
    # after shutdown no pool pods remain allocated
    for node in sim.nodes:
        assert node.used_cpu == pytest.approx(0.0, abs=1e-9)


def test_deterministic_given_seed():
    r1 = _run("worker_pools", n_tiles=80, seed=9)[0]
    r2 = _run("worker_pools", n_tiles=80, seed=9)[0]
    assert r1.makespan == r2.makespan
    assert r1.pods_created == r2.pods_created


# ----------------------------------------------------- paper reproduction --

@pytest.mark.slow
def test_paper_headline_numbers():
    """C2/C3: clustered ≈1700 s, pools ≈1420 s, ≈15-20 % improvement."""
    wp, _, _ = ex.run_model("worker_pools", seed=7)
    cl, _, _ = ex.run_model("clustered", seed=7)
    assert 1340 <= wp.makespan <= 1500, wp.makespan
    assert 1600 <= cl.makespan <= 1820, cl.makespan
    imp = 1 - wp.makespan / cl.makespan
    assert 0.12 <= imp <= 0.25, imp


# ------------------------------------------------- §5 future-work extras ---

def test_vertical_autoscaler_rightsizes():
    from repro.core.extensions import VerticalAutoscaler
    vpa = VerticalAutoscaler(margin=0.2, min_samples=3)
    assert vpa.recommend("t", 1.0) == 1.0           # no data yet
    for _ in range(3):
        vpa.observe("t", 0.5)
    rec = vpa.recommend("t", 1.0)
    assert rec == pytest.approx(0.6)                # 0.5 * 1.2
    vpa.observe("t", 0.9)
    assert vpa.recommend("t", 1.0) == pytest.approx(1.0)  # capped at current


def test_vpa_pools_rightsize_and_pack_more():
    """Paper §5 future work: right-sized requests pack more concurrent
    workers per node at no makespan cost (mProject's 0.85 utilization
    bounds the makespan win itself — recorded honestly in EXPERIMENTS)."""
    from repro.core.extensions import VerticalWorkerPoolExecutor
    wf1 = ex.make_workflow(seed=3, n_tiles=200)
    wf2 = ex.make_workflow(seed=3, n_tiles=200)
    sim1, sim2 = ex.make_sim(seed=3), ex.make_sim(seed=3)
    plain = ex.make_executor("worker_pools")
    vpa = VerticalWorkerPoolExecutor(pooled_types=ex.POOLED_TYPES)
    r_plain = HyperflowEngine(wf1, plain, sim1).run()
    r_vpa = HyperflowEngine(wf2, vpa, sim2).run()
    assert all(t.done for t in wf2.tasks.values())
    # requests right-sized toward true utilization (mDiffFit 0.45 -> ~0.52)
    cpus = {p.type: p.cpu for p in vpa.pools.values()}
    assert cpus["mDiffFit"] < 0.7
    # never slower, and packs more concurrent tasks at peak
    assert r_vpa.makespan <= r_plain.makespan * 1.02
    peak_plain = max(v for _, v in sim1.running_tasks_trace)
    peak_vpa = max(v for _, v in sim2.running_tasks_trace)
    assert peak_vpa > peak_plain


def test_federated_multicluster_executes_with_locality():
    """Paper §5 future work: two-cloud federation — all tasks finish, most
    run in their data-home cluster, stealing pays the transfer penalty."""
    from repro.core.extensions import FederatedWorkerPoolExecutor
    wf = ex.make_workflow(seed=5, n_tiles=120)
    sim = ex.make_sim(seed=5)
    n = len(sim.nodes)
    fed = FederatedWorkerPoolExecutor(
        clusters={"A": range(0, n // 2), "B": range(n // 2, n)},
        pooled_types=None, transfer_penalty=5.0)
    rep = HyperflowEngine(wf, fed, sim).run()
    assert all(t.done for t in wf.tasks.values())
    assert rep.makespan > 0
    # locality honored: stealing happens but is not the norm
    assert fed.stolen < len(wf) * 0.5


def test_federated_cluster_isolation():
    """Pods of cluster A never land on B's nodes."""
    from repro.core.extensions import FederatedWorkerPoolExecutor
    wf = ex.make_workflow(seed=5, n_tiles=60)
    sim = ex.make_sim(seed=5)
    n = len(sim.nodes)
    a_nodes = set(range(0, n // 2))
    fed = FederatedWorkerPoolExecutor(
        clusters={"A": a_nodes, "B": set(range(n // 2, n))})
    HyperflowEngine(wf, fed, sim).run()
    for pod in sim.pods.values():
        if pod.node is None:
            continue
        allowed = getattr(pod, "allowed_nodes", None)
        if allowed is not None:
            assert pod.node in allowed
