"""Distribution-layer tests: policies, step builders (lower on a 1-device
mesh in-process), roofline HLO parsing, and a subprocess full-scale dry-run
smoke (slow)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_cell, build_train_cell, init_train_state
from repro.parallel.policies import default_fsdp, policy_for
from repro.parallel.sharding import ShardingPolicy
from repro.roofline.analysis import model_flops, kernel_traffic
from repro.roofline.hlo_cost import analyze_hlo


# ------------------------------------------------------------- policies ----

def test_policy_divisibility_rules():
    mesh = None  # tp=1 -> everything shardable collapses to None checks

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    # starcoder2: H=36, K=4 -> neither divides 16 -> attention unsharded
    p = policy_for(get_arch("starcoder2-7b"), FakeMesh())
    assert p.rules["kv_heads"] is None and p.rules["qgroup"] is None
    assert p.rules["mlp"] == "model"
    # llama3-405b: G=16 -> qgroup sharded
    p = policy_for(get_arch("llama3-405b"), FakeMesh())
    assert p.rules["qgroup"] == "model"
    # zamba2: K=32 -> kv sharded; ssm heads 112 -> sharded
    p = policy_for(get_arch("zamba2-7b"), FakeMesh())
    assert p.rules["kv_heads"] == "model"
    assert p.rules["ssm_heads"] == "model"
    # granite: 32 experts -> EP; whisper vocab odd -> unsharded
    p = policy_for(get_arch("granite-moe-1b-a400m"), FakeMesh())
    assert p.rules["experts"] == "model"
    p = policy_for(get_arch("whisper-base"), FakeMesh())
    assert p.rules["vocab"] is None
    # batch degrades for batch=1
    p = policy_for(get_arch("zamba2-7b"), FakeMesh(), global_batch=1)
    assert p.rules["batch"] is None


def test_default_fsdp_thresholds():
    assert default_fsdp(get_arch("llama3-405b"), "train")
    assert not default_fsdp(get_arch("xlstm-125m"), "train")
    assert default_fsdp(get_arch("llama3-405b"), "decode")
    assert not default_fsdp(get_arch("llama3.2-3b"), "decode")


# ---------------------------------------------------------- step builder ---

@pytest.mark.parametrize("arch", ["llama3.2-3b", "mixtral-8x7b",
                                  "xlstm-125m", "zamba2-7b", "whisper-base"])
def test_build_train_cell_lowers_on_host_mesh(arch):
    cfg = get_arch(arch).reduced()
    shape = ShapeConfig("t", 16, 4, "train", microbatch=2)
    mesh = make_mesh((1, 1), ("data", "model"))
    cell = build_train_cell(cfg, shape, mesh, fsdp=False)
    lowered = cell.lower()
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_train_cell_executes_and_descends():
    cfg = get_arch("llama3.2-3b").reduced()
    shape = ShapeConfig("t", 16, 8, "train", microbatch=4)
    mesh = make_mesh((1, 1), ("data", "model"))
    from repro.data import make_batch_fn
    from repro.models import build_model
    from repro.optim import AdamWConfig
    opt = AdamWConfig(lr=1e-2, warmup_steps=3, total_steps=200,
                      moment_dtype=cfg.opt_dtype)
    cell = build_train_cell(cfg, shape, mesh, fsdp=False, opt=opt)
    step = cell.jitted()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    batch_fn = make_batch_fn(cfg, shape, 0)
    losses = []
    for i in range(30):
        state, metrics = step(state, batch_fn(i))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


@pytest.mark.parametrize("kind", ["prefill", "decode"])
def test_build_serve_cell_lowers(kind):
    cfg = get_arch("mixtral-8x7b").reduced()
    shape = ShapeConfig("s", 32, 4, kind)
    mesh = make_mesh((1, 1), ("data", "model"))
    cell = build_cell(cfg, shape, mesh, fsdp=False)
    compiled = cell.lower().compile()
    assert compiled is not None


# ------------------------------------------------------------- roofline ----

HLO_SAMPLE = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[2,4]<=[8]
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  ROOT %w = (s32[], f32[8,8]{1,0}) while(%init), condition=%cond, body=%body
}
"""


def test_hlo_cost_trip_counts_and_collectives():
    cost = analyze_hlo(HLO_SAMPLE, n_devices=8)
    # dot: 2*8*8*8 flops, x5 loop trips
    assert cost.flops == pytest.approx(2 * 8 * 8 * 8 * 5)
    ar = cost.collectives["all-reduce"]
    assert ar["count"] == 5
    assert ar["bytes"] == pytest.approx(8 * 8 * 4 * 5)
    # ring: 2(N-1)/N with N=4
    assert ar["ring_bytes"] == pytest.approx(2 * 3 / 4 * 8 * 8 * 4 * 5)


def test_model_flops_sanity():
    arch = get_arch("llama3.2-3b")
    tr = model_flops(arch, SHAPES["train_4k"])
    pf = model_flops(arch, SHAPES["prefill_32k"])
    de = model_flops(arch, SHAPES["decode_32k"])
    assert tr > pf > de > 0
    # train ~ 6ND: N~3.2e9 (tied embeddings), D~1.05e6
    assert 1e16 < tr < 6e16


def test_dryrun_artifacts_complete():
    """The committed dry-run sweep must cover every (arch x shape x mesh)
    cell: compiled or skipped-by-design, never error."""
    art = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    missing, errors = [], []
    for a in ARCHS:
        for s in SHAPES:
            for m in ("pod", "multipod"):
                f = art / f"{a}_{s}_{m}.json"
                if not f.exists():
                    missing.append(f.name)
                    continue
                d = json.loads(f.read_text())
                if "error" in d:
                    errors.append(f.name)
                ok, _ = shape_applicable(ARCHS[a], SHAPES[s])
                if not ok:
                    assert "skipped" in d
    assert not missing, f"missing cells: {missing}"
    assert not errors, f"failed cells: {errors}"


@pytest.mark.slow
def test_full_scale_dryrun_subprocess():
    """One real 256-chip AOT compile in a fresh process (the 512-device
    host-platform flag must be set before jax import)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "xlstm-125m",
         "--shape", "decode_32k", "--mesh", "pod", "--tag", "testsmoke"],
        capture_output=True, text=True, timeout=560,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        cwd=Path(__file__).resolve().parents[1])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "terms:" in r.stdout


HLO_FUSION_SAMPLE = """
HloModule ftest

%fused_computation.1 (param_0.1: f32[16,64,8], param_1.1: f32[8,8], param_2.1: s32[]) -> f32[16,64,8] {
  %param_0.1 = f32[16,64,8]{2,1,0} parameter(0)
  %param_1.1 = f32[8,8]{1,0} parameter(1)
  %bc = f32[1,8,8]{2,1,0} bitcast(%param_1.1)
  %param_2.1 = s32[] parameter(2)
  %zero = s32[] constant(0)
  ROOT %dus = f32[16,64,8]{2,1,0} dynamic-update-slice(%param_0.1, %bc, %param_2.1, %zero, %zero)
}

ENTRY %main (a: f32[16,64,8], u: f32[8,8], i: s32[]) -> f32[16,64,8] {
  %a = f32[16,64,8]{2,1,0} parameter(0)
  %u = f32[8,8]{1,0} parameter(1)
  %i = s32[] parameter(2)
  ROOT %fusion.1 = f32[16,64,8]{2,1,0} fusion(%a, %u, %i), kind=kLoop, calls=%fused_computation.1
}
"""


def test_hlo_cost_inplace_dus_fusion():
    """A DUS-root fusion must be charged at update size (in-place), not the
    full buffer: read(update-slice via consumer analysis) + small operands
    + write(update)."""
    cost = analyze_hlo(HLO_FUSION_SAMPLE, n_devices=1)
    full = 16 * 64 * 8 * 4
    upd = 8 * 8 * 4
    # far less than read+write of the full buffer
    assert cost.bytes_accessed < 0.25 * (2 * full)
    assert cost.bytes_accessed >= 2 * upd
