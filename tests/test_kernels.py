"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes/dtypes (+ hypothesis property sweeps), plus the blocked
XLA flash path vs the same oracle (fwd AND grads)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.models.attention import flash_attention as flash_xla

jax.config.update("jax_enable_x64", False)


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.5).astype(dtype)


def _qkv(seed, B, Sq, Skv, K, G, H, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (_rand(k1, (B, Sq, K, G, H), dtype),
            _rand(k2, (B, Skv, K, H), dtype),
            _rand(k3, (B, Skv, K, H), dtype))


TOL = dict(rtol=2e-2, atol=2e-2)


# ------------------------------------------------------ flash attention ----

@pytest.mark.parametrize("B,S,K,G,H", [
    (1, 128, 1, 1, 32), (2, 256, 2, 2, 64), (1, 512, 2, 3, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_fwd_vs_ref(B, S, K, G, H, causal):
    q, k, v = _qkv(0, B, S, S, K, G, H)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_flash_fwd_window():
    q, k, v = _qkv(1, 2, 256, 256, 2, 1, 64)
    out = ops.flash_attention(q, k, v, causal=True, window=64,
                              block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_flash_fwd_kv_valid():
    q, k, v = _qkv(2, 1, 128, 256, 2, 2, 32)
    out = ops.flash_attention(q, k, v, causal=False, kv_valid=100,
                              block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=False, kv_valid=100)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_flash_fwd_bf16():
    q, k, v = _qkv(3, 1, 256, 256, 1, 2, 64, dtype=jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@settings(max_examples=12, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64, 128]),
    s_mult=st.integers(2, 4),
    causal=st.booleans(),
    g=st.integers(1, 3),
)
def test_flash_fwd_block_shape_sweep(bq, bk, s_mult, causal, g):
    S = 128 * s_mult
    q, k, v = _qkv(4, 1, S, S, 2, g, 32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


# ----------------------------------------------- blocked XLA flash path ----

@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
def test_flash_xla_fwd_vs_ref(causal, window):
    q, k, v = _qkv(5, 2, 256, 256, 2, 2, 32)
    out = flash_xla(q, k, v, causal=causal, window=window,
                    block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_flash_xla_grads_vs_ref():
    q, k, v = _qkv(6, 1, 256, 256, 2, 2, 32)

    def f_blocked(q, k, v):
        return jnp.sum(flash_xla(q, k, v, causal=True,
                                 block_q=64, block_k=64) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_blocked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=5e-2)


def test_flash_xla_decode_kv_valid_per_batch():
    q, k, v = _qkv(7, 3, 1, 256, 2, 2, 32)
    kv_valid = jnp.array([10, 100, 256], jnp.int32)
    out = flash_xla(q, k, v, causal=False, kv_valid=kv_valid,
                    block_q=16, block_k=64)
    for b in range(3):
        want = ref.attention_ref(q[b:b + 1], k[b:b + 1], v[b:b + 1],
                                 causal=False, kv_valid=int(kv_valid[b]))
        np.testing.assert_allclose(np.asarray(out[b:b + 1]),
                                   np.asarray(want), **TOL)


# --------------------------------------------------------- flash decode ----

@pytest.mark.parametrize("valid", [1, 63, 128, 500, 512])
def test_flash_decode_vs_ref(valid):
    q, k, v = _qkv(8, 2, 1, 512, 2, 4, 64)
    out = ops.flash_decode(q, k, v, valid, block_k=128)
    want = ref.decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), **TOL)


def test_flash_decode_matches_flash_attention():
    q, k, v = _qkv(9, 1, 1, 256, 2, 2, 32)
    a = ops.flash_decode(q, k, v, 200, block_k=64)
    b = ops.flash_attention(q, k, v, causal=False, kv_valid=200,
                            block_q=16, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


# -------------------------------------------------------------- rmsnorm ----

@settings(max_examples=10, deadline=None)
@given(rows=st.sampled_from([64, 256, 512]),
       d=st.sampled_from([128, 256, 768]),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_rmsnorm_vs_ref(rows, d, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = _rand(k1, (rows, d), dtype)
    scale = _rand(k2, (d,), jnp.float32) + 1.0
    out = ops.rmsnorm(x, scale, block_rows=64)
    want = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_rmsnorm_residual_vs_ref():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    x = _rand(k1, (128, 256), jnp.float32)
    r = _rand(k2, (128, 256), jnp.float32)
    scale = _rand(k3, (256,), jnp.float32) + 1.0
    y, new_r = ops.rmsnorm_residual(x, r, scale, block_rows=64)
    want_y, want_r = ref.rmsnorm_residual_ref(x, r, scale)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want_y), **TOL)
    np.testing.assert_allclose(np.asarray(new_r), np.asarray(want_r), **TOL)


# ------------------------------------------------------------- ssd scan ----

@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (256, 256)])
def test_ssd_scan_vs_sequential_ref(S, chunk):
    B, H, P, N = 2, 3, 16, 8
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(3), 4)
    x = _rand(k1, (B, S, H, P), jnp.float32)
    a = -jnp.abs(_rand(k2, (B, S, H), jnp.float32)) * 0.1
    Bm = _rand(k3, (B, S, N), jnp.float32)
    Cm = _rand(k4, (B, S, N), jnp.float32)
    out = ops.ssd_scan(x, a, Bm, Cm, chunk=chunk)
    want = ref.ssd_ref(x, a, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


def test_ssd_scan_matches_model_chunked():
    """The model's jnp chunked SSD and the Pallas kernel agree."""
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 1, 128, 2, 16, 8
    keys = jax.random.split(jax.random.PRNGKey(4), 4)
    x = _rand(keys[0], (B, S, H, P), jnp.float32)
    a = -jnp.abs(_rand(keys[1], (B, S, H), jnp.float32)) * 0.1
    Bm = _rand(keys[2], (B, S, N), jnp.float32)
    Cm = _rand(keys[3], (B, S, N), jnp.float32)
    out = ops.ssd_scan(x, a, Bm, Cm, chunk=32)
    want, _ = ssd_chunked(x, a, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-2, atol=3e-2)


# ------------------------------------------------- pallas flash backward ---

@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 96)])
def test_flash_pallas_grads_vs_ref(causal, window):
    """Pallas fwd+bwd kernels vs the jnp oracle gradients."""
    q, k, v = _qkv(10, 1, 256, 256, 2, 2, 32)

    def f_pallas(q, k, v):
        return jnp.sum(ops.flash_attention_diff(q, k, v, causal, window,
                                                None, 64, 64) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=causal,
                                         window=window) ** 2)

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(g1, g2, ("dq", "dk", "dv")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=5e-2, err_msg=name)


def test_flash_pallas_grads_gqa_groups():
    """GQA: dk/dv must sum over the folded G group rows correctly."""
    q, k, v = _qkv(11, 2, 128, 128, 2, 3, 32)

    def f_pallas(q, k, v):
        return jnp.sum(ops.flash_attention_diff(q, k, v, True, 0,
                                                None, 64, 64) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-2, atol=5e-2)
