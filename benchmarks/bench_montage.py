"""Paper figures 3-6 + the §4.4 makespan comparison.

One function per paper artifact:
  fig3_job_model        — job model collapses (small workflow, like the paper)
  fig4_clustering       — clustered 16k run + utilization trace
  fig5_clustering_sweep — clustering parameter sweep (no config satisfies)
  fig6_worker_pools     — worker pools 16k run, full-capacity utilization
  makespan_table        — pools vs best clustering: the ≈20 % headline
"""
from __future__ import annotations

import statistics

from benchmarks.common import Row, ascii_trace, timed
from repro.core import experiment as ex

SEEDS = (7, 11, 13)


def fig3_job_model(verbose=False):
    (rep, wf, sim), us = timed(ex.run_model, "job", seed=7, n_tiles=400)
    if verbose:
        print(ascii_trace(ex.utilization_windows(sim, 50)[:40]))
    return [("fig3_job_model_small_makespan_s", us,
             f"{rep.makespan:.0f}"),
            ("fig3_job_model_small_utilization", us,
             f"{rep.utilization:.3f}"),
            ("fig3_job_model_small_pods", us, str(rep.pods_created))]


def fig4_clustering(verbose=False):
    (rep, wf, sim), us = timed(ex.run_model, "clustered", seed=7)
    if verbose:
        print(ascii_trace(ex.utilization_windows(sim, 50)))
    return [("fig4_clustered_16k_makespan_s", us, f"{rep.makespan:.0f}"),
            ("fig4_clustered_16k_utilization", us,
             f"{rep.utilization:.3f}"),
            ("fig4_clustered_16k_pods", us, str(rep.pods_created))]


def fig5_clustering_sweep(verbose=False):
    rows = []
    sweeps = {
        "paper_5_20": ex.CLUSTERING_RULES,
        "small_2_5": {"mProject": {"size": 2, "timeoutMs": 3000},
                      "mDiffFit": {"size": 5, "timeoutMs": 3000},
                      "mBackground": {"size": 5, "timeoutMs": 3000}},
        "large_10_50": {"mProject": {"size": 10, "timeoutMs": 3000},
                        "mDiffFit": {"size": 50, "timeoutMs": 3000},
                        "mBackground": {"size": 50, "timeoutMs": 3000}},
        "huge_20_100": {"mProject": {"size": 20, "timeoutMs": 5000},
                        "mDiffFit": {"size": 100, "timeoutMs": 5000},
                        "mBackground": {"size": 100, "timeoutMs": 5000}},
    }
    for name, rules in sweeps.items():
        (rep, _, _), us = timed(ex.run_model, "clustered", seed=7,
                                rules=rules)
        rows.append((f"fig5_clustering_{name}_makespan_s", us,
                     f"{rep.makespan:.0f}"))
    return rows


def fig6_worker_pools(verbose=False):
    (rep, wf, sim), us = timed(ex.run_model, "worker_pools", seed=7)
    if verbose:
        print(ascii_trace(ex.utilization_windows(sim, 50)))
    return [("fig6_pools_16k_makespan_s", us, f"{rep.makespan:.0f}"),
            ("fig6_pools_16k_utilization", us, f"{rep.utilization:.3f}"),
            ("fig6_pools_16k_pods", us, str(rep.pods_created))]


def makespan_table(verbose=False):
    pools, clustered = [], []
    us_tot = 0.0
    for s in SEEDS:
        (rp, _, _), us1 = timed(ex.run_model, "worker_pools", seed=s)
        (rc, _, _), us2 = timed(ex.run_model, "clustered", seed=s)
        pools.append(rp.makespan)
        clustered.append(rc.makespan)
        us_tot += us1 + us2
    mp, mc = statistics.mean(pools), statistics.mean(clustered)
    imp = 100 * (1 - mp / mc)
    return [
        ("table_pools_makespan_avg_s", us_tot, f"{mp:.0f}"),
        ("table_clustered_makespan_avg_s", us_tot, f"{mc:.0f}"),
        ("table_improvement_pct", us_tot, f"{imp:.1f}"),
        ("table_paper_pools_s", 0.0, "1420"),
        ("table_paper_clustered_s", 0.0, "1700"),
        ("table_paper_improvement_pct", 0.0, "16.5"),
    ]


def run(verbose=False):
    rows = []
    rows += fig3_job_model(verbose)
    rows += fig4_clustering(verbose)
    rows += fig5_clustering_sweep(verbose)
    rows += fig6_worker_pools(verbose)
    rows += makespan_table(verbose)
    return rows
