"""Kernel microbenches: XLA blocked flash path wall time (the path the
dry-run lowers) + Pallas-kernel parity error vs the jnp oracle, + derived
GFLOP counts. Interpret-mode wall times are NOT perf-meaningful on CPU (the
kernels target TPU); parity is the point."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels import ops, ref
from repro.models.attention import flash_attention as flash_xla


def _bench(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(verbose=False):
    rows = []
    B, S, K, G, H = 1, 1024, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, K, G, H), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, H), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, H), jnp.float32)
    flops = 4 * B * S * S * K * G * H / 2          # causal

    f = jax.jit(lambda q, k, v: flash_xla(q, k, v, causal=True,
                                          block_q=256, block_k=256))
    us = _bench(f, q, k, v)
    rows.append(("kernel_flash_xla_fwd_1k", us, f"{flops/1e9:.2f}GF"))

    out_k = ops.flash_attention(q, k, v, causal=True, block_q=256,
                                block_k=256)
    want = ref.attention_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(out_k - want)))
    rows.append(("kernel_flash_pallas_parity_maxerr", 0.0, f"{err:.2e}"))

    qd = q[:, :1]
    us = _bench(jax.jit(lambda q, k, v: ops.flash_decode(q, k, v, S,
                                                         block_k=256)),
                qd, k, v)
    errd = float(jnp.max(jnp.abs(
        ops.flash_decode(qd, k, v, S, block_k=256)
        - ref.decode_ref(qd, k, v, S))))
    rows.append(("kernel_flash_decode_parity_maxerr", us, f"{errd:.2e}"))

    x = jax.random.normal(ks[0], (2048, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    err = float(jnp.max(jnp.abs(ops.rmsnorm(x, sc) - ref.rmsnorm_ref(x, sc))))
    rows.append(("kernel_rmsnorm_parity_maxerr",
                 _bench(jax.jit(ref.rmsnorm_ref), x, sc), f"{err:.2e}"))

    Bs, Ss, Hs, P, N = 1, 256, 2, 16, 16
    xs = jax.random.normal(ks[0], (Bs, Ss, Hs, P)) * 0.3
    a = -jnp.abs(jax.random.normal(ks[1], (Bs, Ss, Hs))) * 0.1
    Bm = jax.random.normal(ks[2], (Bs, Ss, N)) * 0.3
    Cm = jax.random.normal(ks[0], (Bs, Ss, N)) * 0.3
    err = float(jnp.max(jnp.abs(ops.ssd_scan(xs, a, Bm, Cm, chunk=64)
                                - ref.ssd_ref(xs, a, Bm, Cm))))
    rows.append(("kernel_ssd_parity_maxerr", 0.0, f"{err:.2e}"))
    return rows
