"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import List, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def ascii_trace(windows, width: int = 60, height_cap: float = 1.0) -> str:
    out = []
    for t, u in windows:
        bar = "#" * int(min(u, height_cap) / height_cap * width)
        out.append(f"{t:7.0f}s |{bar:<{width}s}| {u:4.2f}")
    return "\n".join(out)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
