"""Paper §5 future-work extensions: vertical pod auto-scaling and
multi-cluster (multi-cloud) federation."""
from __future__ import annotations

from benchmarks.common import timed
from repro.core import experiment as ex
from repro.core.engine import HyperflowEngine
from repro.core.extensions import (FederatedWorkerPoolExecutor,
                                   VerticalWorkerPoolExecutor)


def run(verbose=False):
    rows = []
    # VPA: over-provisioned requests right-sized
    wf1, wf2 = ex.make_workflow(seed=7, n_tiles=800), ex.make_workflow(
        seed=7, n_tiles=800)
    sim1, sim2 = ex.make_sim(seed=7), ex.make_sim(seed=7)
    (r_plain), us1 = timed(
        HyperflowEngine(wf1, ex.make_executor("worker_pools"), sim1).run)
    vpa = VerticalWorkerPoolExecutor(pooled_types=ex.POOLED_TYPES)
    (r_vpa), us2 = timed(HyperflowEngine(wf2, vpa, sim2).run)
    peak1 = max(v for _, v in sim1.running_tasks_trace)
    peak2 = max(v for _, v in sim2.running_tasks_trace)
    rows += [
        ("vpa_plain_makespan_s", us1, f"{r_plain.makespan:.0f}"),
        ("vpa_rightsized_makespan_s", us2, f"{r_vpa.makespan:.0f}"),
        ("vpa_peak_concurrency_plain", us1, str(peak1)),
        ("vpa_peak_concurrency_rightsized", us2, str(peak2)),
        ("vpa_mDiffFit_request", us2,
         f"{vpa.pools['mDiffFit'].cpu:.2f}"),
    ]
    # Federation: two 34-core clouds vs one 68-core cloud
    wf3 = ex.make_workflow(seed=7, n_tiles=800)
    sim3 = ex.make_sim(seed=7)
    n = len(sim3.nodes)
    fed = FederatedWorkerPoolExecutor(
        clusters={"A": range(0, n // 2), "B": range(n // 2, n)},
        transfer_penalty=5.0)
    (r_fed), us3 = timed(HyperflowEngine(wf3, fed, sim3).run)
    rows += [
        ("multicloud_federated_makespan_s", us3, f"{r_fed.makespan:.0f}"),
        ("multicloud_single_makespan_s", us1, f"{r_plain.makespan:.0f}"),
        ("multicloud_stolen_tasks", us3, str(fed.stolen)),
        ("multicloud_overhead_pct", us3,
         f"{100 * (r_fed.makespan / r_plain.makespan - 1):.1f}"),
    ]
    return rows
