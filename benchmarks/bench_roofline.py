"""§Roofline table: reads the dry-run artifacts and prints per-cell terms.
Baseline rows for all 40 cells x 2 meshes; the hillclimbed variants carry a
tag suffix."""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(tagged=False):
    cells = []
    for f in sorted(ART.glob("*.json")):
        d = json.loads(f.read_text())
        is_tagged = bool(d.get("tag"))
        if is_tagged != tagged:
            continue
        cells.append(d)
    return cells


def run(verbose=False):
    rows = []
    compiled = skipped = 0
    worst = (None, 1e9)
    for d in load_cells():
        key = f'{d["arch"]}.{d["shape"]}.{d["mesh"]}'
        if "skipped" in d:
            skipped += 1
            rows.append((f"roofline_{key}", 0.0, "SKIP"))
            continue
        if "error" in d:
            rows.append((f"roofline_{key}", 0.0, "ERROR"))
            continue
        compiled += 1
        rf = d["roofline_fraction"]
        rows.append((
            f"roofline_{key}", d["compile_seconds"] * 1e6,
            f"dom={d['dominant']};rf={rf:.3f};"
            f"c={d['compute_term_kernelized']*1e3:.0f}ms;"
            f"m={d['memory_term_kernelized']*1e3:.0f}ms;"
            f"x={d['collective_term_ring']*1e3:.0f}ms"))
        if d["shape"] != "decode_32k" and d["shape"] != "long_500k" \
                and rf < worst[1]:
            worst = (key, rf)
    rows.append(("roofline_cells_compiled", 0.0, str(compiled)))
    rows.append(("roofline_cells_skipped_by_design", 0.0, str(skipped)))
    if worst[0]:
        rows.append(("roofline_worst_nondecode_cell", 0.0,
                     f"{worst[0]}:rf={worst[1]:.3f}"))
    for d in load_cells(tagged=True):
        key = f'{d["arch"]}.{d["shape"]}.{d["mesh"]}.{d["tag"]}'
        rows.append((
            f"perf_{key}", d["compile_seconds"] * 1e6,
            f"dom={d['dominant']};rf={d['roofline_fraction']:.3f};"
            f"c={d['compute_term_kernelized']*1e3:.0f}ms;"
            f"m={d['memory_term_kernelized']*1e3:.0f}ms;"
            f"x={d['collective_term_ring']*1e3:.0f}ms"))
    return rows
