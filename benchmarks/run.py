"""Benchmark harness — one module per paper table/figure + the beyond-paper
ML-fleet, kernel-parity, and roofline benches. Prints ``name,us_per_call,
derived`` CSV rows (derived carries the figure-of-merit)."""
import argparse
import sys
import traceback

from benchmarks.common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: montage,ml_pools,kernels,roofline")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (bench_extensions, bench_kernels,
                            bench_ml_pools, bench_montage, bench_roofline)
    benches = {
        "montage": bench_montage.run,
        "ml_pools": bench_ml_pools.run,
        "kernels": bench_kernels.run,
        "roofline": bench_roofline.run,
        "extensions": bench_extensions.run,
    }
    print("name,us_per_call,derived")
    failed = 0
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            emit(fn(verbose=args.verbose))
        except Exception:
            failed += 1
            print(f"{name},0,BENCH_FAILED", file=sys.stdout)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()
