"""Beyond-paper: the worker-pool execution model applied to an ML fleet.

1. FleetSim — 16 mesh slices serving a mixed train+decode+prefill workload;
   job dispatch (per-task compile) vs persistent pools with proportional
   autoscaling. Costs come from the dry-run artifacts (compile seconds,
   roofline-bound step seconds).
2. SlicePoolExecutor — REAL execution on this host (reduced configs):
   wall-clock amortization of XLA compilation by pools vs per-task dispatch.
"""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.engine import CompileCostModel, FleetSim, MLTask, SlicePoolExecutor


def fleet_sim():
    # request-granular serving (the paper's "short tasks" regime: per-task
    # dispatch overhead ~ compile+load rivals the work itself) + one train
    # job as a chain of checkpointable segments
    fleet = FleetSim(n_slices=16)
    serve = [MLTask("llama3.2-3b", "decode_32k", steps=8)
             for _ in range(400)]
    serve += [MLTask("mixtral-8x7b", "prefill_32k", steps=3)
              for _ in range(150)]
    chains = [[MLTask("llama3.2-3b", "train_4k", steps=20)
               for _ in range(6)]]
    rows = []
    for model in ("job", "worker_pools"):
        wf = fleet.workload(serve, chains=chains)
        (rep), us = timed(fleet.run, wf, model=model)
        rows.append((f"mlfleet_{model}_makespan_s", us,
                     f"{rep.makespan:.0f}"))
        rows.append((f"mlfleet_{model}_utilization", us,
                     f"{rep.utilization:.3f}"))
        rows.append((f"mlfleet_{model}_dispatches", us,
                     str(rep.pods_created)))
    return rows


def real_executor():
    rows = []
    tasks = [("xlstm-125m", "train"), ("xlstm-125m", "train"),
             ("granite-moe-1b-a400m", "decode"),
             ("granite-moe-1b-a400m", "decode")]
    for mode in ("job", "pool"):
        ex = SlicePoolExecutor(mode=mode)
        total_setup = total_run = 0.0
        for arch, kind in tasks:
            out = ex.run_task(arch, kind, steps=2)
            total_setup += out["setup_s"]
            total_run += out["run_s"]
        rows.append((f"mlreal_{mode}_setup_s", total_setup * 1e6,
                     f"{total_setup:.2f}"))
        rows.append((f"mlreal_{mode}_run_s", total_run * 1e6,
                     f"{total_run:.2f}"))
    return rows


def run(verbose=False):
    return fleet_sim() + real_executor()
