"""End-to-end training driver: a ~small-but-real LM trained for a few
hundred steps with the full production substrate — sharded-ready step
builders, AdamW, deterministic pipeline, async checkpointing, fault
injection + restart, straggler monitoring.

Default is a ~1M-param xLSTM (CPU-friendly); --mid trains a ~25M model.

    PYTHONPATH=src python examples/train_e2e.py --steps 200
    PYTHONPATH=src python examples/train_e2e.py --steps 200 --fault-at 80
"""
import argparse
import dataclasses
import shutil
import time

import jax

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import make_batch_fn
from repro.engine.fault_tolerance import FaultInjector, TrainSupervisor
from repro.models import build_model, count_params
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--mid", action="store_true", help="~25M params")
    ap.add_argument("--fault-at", type=int, nargs="*", default=[])
    ap.add_argument("--ckpt", default="/tmp/repro_train_e2e")
    args = ap.parse_args()

    cfg = get_arch("xlstm-125m").reduced()
    if args.mid:
        cfg = dataclasses.replace(cfg, d_model=256, num_layers=6,
                                  vocab_size=8192, name="xlstm-mid")
    model = build_model(cfg)
    print(f"training {cfg.name}: {count_params(cfg):,} params, "
          f"{args.steps} steps")
    shape = ShapeConfig("e2e", seq_len=64, global_batch=16, kind="train")
    batch_fn = make_batch_fn(cfg, shape, seed=0)
    opt = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)

    @jax.jit
    def train_step(state, batch):
        (loss, m), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        p, o, stats = adamw_update(state["params"], grads,
                                   {k: state[k] for k in ("m", "v", "step")},
                                   opt)
        return {"params": p, **o}, {"loss": loss, **stats}

    def make_state():
        params = model.init(jax.random.PRNGKey(0))
        return {"params": params, **adamw_init(params, opt)}

    shutil.rmtree(args.ckpt, ignore_errors=True)
    sup = TrainSupervisor(
        args.ckpt, make_state,
        lambda s, i: train_step(s, batch_fn(i)),
        every=40,
        injector=FaultInjector(tuple(args.fault_at)) if args.fault_at
        else None)
    t0 = time.time()
    state, log, restarts = sup.run(args.steps)
    for s, m in log:
        if s % 25 == 0 or s == args.steps:
            print(f"step {s:4d}  loss={float(m['loss']):.4f}  "
                  f"lr={float(m['lr']):.2e}")
    first, last = float(log[0][1]["loss"]), float(log[-1][1]["loss"])
    med = sup.monitor.median()
    print(f"\nloss {first:.3f} -> {last:.3f}; {restarts} restart(s); "
          f"median step {med*1e3:.0f}ms; wall {time.time()-t0:.0f}s")
    assert last < first, "loss must improve"


if __name__ == "__main__":
    main()
