"""End-to-end driver: a cloud-native ML serving fleet under the paper's
worker-pool model — REAL models (reduced configs) behind per-(arch x kind)
pools with queue-driven dispatch, vs per-request cold dispatch.

    PYTHONPATH=src python examples/serve_fleet.py
"""
import random
import time

from repro.engine import SlicePoolExecutor


def run_fleet(mode: str, requests):
    ex = SlicePoolExecutor(mode=mode)
    t0 = time.perf_counter()
    setup = run = 0.0
    for arch, kind in requests:
        out = ex.run_task(arch, kind, steps=2)
        setup += out["setup_s"]
        run += out["run_s"]
    wall = time.perf_counter() - t0
    n_compiles = len(ex.compile_events)
    return wall, setup, run, n_compiles


def main():
    rng = random.Random(0)
    archs = ["xlstm-125m", "granite-moe-1b-a400m", "llama3.2-3b"]
    requests = [(rng.choice(archs), rng.choice(["decode", "train"]))
                for _ in range(9)]
    print(f"workload: {len(requests)} mixed requests over {len(archs)} archs")
    for mode in ("job", "pool"):
        wall, setup, run, n = run_fleet(mode, requests)
        print(f"{mode:5s}: wall={wall:6.1f}s  setup={setup:6.1f}s "
              f"run={run:5.2f}s  compiles={n}")
    print("pool mode pays one compile per (arch x kind) pool; job mode pays "
          "it per request — the paper's pod-creation overhead, reincarnated.")


if __name__ == "__main__":
    main()
