"""Quickstart: build an arch from the zoo, train it for real on CPU, then
serve it (prefill + decode) — the full public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py [--arch llama3.2-3b]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import make_batch_fn
from repro.models import build_model, count_params
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()       # tiny same-family config
    print(f"arch={cfg.name} family={cfg.family} "
          f"params={count_params(cfg):,}")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    shape = ShapeConfig("quick", seq_len=32, global_batch=8, kind="train")
    batch_fn = make_batch_fn(cfg, shape, seed=0)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=args.steps)
    state = {"params": params, **adamw_init(params, opt)}

    @jax.jit
    def step(state, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        p, o, _ = adamw_update(state["params"], grads,
                               {k: state[k] for k in ("m", "v", "step")}, opt)
        return {"params": p, **o}, loss

    first = None
    t0 = time.time()
    for i in range(args.steps):
        state, loss = step(state, batch_fn(i))
        if first is None:
            first = float(loss)
        if (i + 1) % 10 == 0:
            print(f"step {i+1:3d}  loss={float(loss):.4f}")
    print(f"loss {first:.3f} -> {float(loss):.3f} "
          f"in {time.time()-t0:.1f}s ({'improved' if float(loss) < first else 'check lr'})")

    # --- serve it ---
    B, P, G = 2, 16, 8
    cache = model.init_cache(B, P + G, dtype=jnp.float32)
    batch = {"tokens": jnp.ones((B, P), jnp.int32)}
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.zeros((B, cfg.num_image_tokens,
                                           cfg.d_model))
    logits, cache = jax.jit(model.prefill)(state["params"], batch, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    decode = jax.jit(model.decode_step)
    for i in range(G - 1):
        logits, cache = decode(state["params"], tok, cache, jnp.int32(P + i))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print(f"greedy continuation tokens: {out}")


if __name__ == "__main__":
    main()
