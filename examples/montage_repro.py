"""Reproduce the paper's experiment end-to-end: the 16k-task Montage
workflow on the 17x4-core cluster under all three execution models, with
utilization traces (the paper's Figs. 3-6) and the makespan table.

    PYTHONPATH=src python examples/montage_repro.py            # full 16k
    PYTHONPATH=src python examples/montage_repro.py --tiles 400  # quick
"""
import argparse

from repro.core import experiment as ex


def trace(sim, width=56):
    for t, u in ex.utilization_windows(sim, 50.0):
        print(f"{t:6.0f}s |{'#' * int(u * width):<{width}s}| {u:4.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiles", type=int, default=ex.N_TILES)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--no-trace", action="store_true")
    args = ap.parse_args()

    results = {}
    # the paper ran the plain job model only on a smaller workflow (§4.2)
    job_tiles = min(args.tiles, 400)
    for model, tiles in (("job", job_tiles), ("clustered", args.tiles),
                         ("worker_pools", args.tiles)):
        rep, wf, sim = ex.run_model(model, seed=args.seed, n_tiles=tiles)
        results[model] = rep
        print(f"\n=== {model} ({tiles} tiles, {len(wf)} tasks) ===")
        print(f"makespan={rep.makespan:.0f}s  util={rep.utilization:.3f}  "
              f"pods={rep.pods_created}  sched_attempts={rep.sched_attempts}")
        if not args.no_trace and model != "job":
            trace(sim)

    wp, cl = results["worker_pools"], results["clustered"]
    print("\n=== paper comparison (16k Montage, 68 cores) ===")
    print(f"{'model':15s} {'ours':>8s} {'paper':>8s}")
    print(f"{'worker pools':15s} {wp.makespan:7.0f}s {'~1420s':>8s}")
    print(f"{'clustered jobs':15s} {cl.makespan:7.0f}s {'~1700s':>8s}")
    print(f"{'improvement':15s} {100*(1-wp.makespan/cl.makespan):6.1f}% "
          f"{'~16.5%':>8s}")
    print(f"{'job model':15s} {'collapses':>8s} {'collapses':>9s} "
          f"(util {results['job'].utilization:.2f} on the small instance)")


if __name__ == "__main__":
    main()
