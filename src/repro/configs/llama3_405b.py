"""Llama-3-405B [arXiv:2407.21783; unverified] — 126L GQA kv=8, 128k vocab.

Memory note (v5e, 16 GB HBM): full train state needs bf16 AdamW moments
(8 B/param fully sharded = 12.7 GB/chip on a 256-chip pod) — set via
``opt_dtype``. fp32 moments fit only on the 512-chip multi-pod mesh.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    rope="full",
    norm="rmsnorm",
    mlp="swiglu",
    opt_dtype="bfloat16",
)
