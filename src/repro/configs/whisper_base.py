"""Whisper-base [arXiv:2212.04356; unverified] — enc-dec, conv frontend STUB.

The audio conv frontend is stubbed per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, encoder_seq, d_model). Attention is
MHA (kv=8 == heads), learned positions (rope="none").
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    rope="none",
    norm="layernorm",
    mlp="gelu",
    max_position_embeddings=32768,   # stretched for the decode_32k cell
    encoder_layers=6,
    encoder_seq=1500,
)
