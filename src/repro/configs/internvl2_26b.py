"""InternVL2-26B [arXiv:2404.16821; hf] — VLM: InternViT frontend STUB.

Backbone-only per the assignment: the vision tower is stubbed; ``input_specs``
provides precomputed patch embeddings (B, num_image_tokens, d_model) that the
model overlays on the first ``num_image_tokens`` positions of the token
embedding sequence (LLaVA-style prefix).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    rope="full",
    norm="rmsnorm",
    mlp="swiglu",
    num_image_tokens=256,
)
