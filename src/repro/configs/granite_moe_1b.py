"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

MoE 32 experts top-8, narrow d_ff=512 experts.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    rope="full",
    norm="rmsnorm",
    mlp="swiglu",
    moe=MoEConfig(num_experts=32, top_k=8),
    tie_embeddings=True,
)
