"""xLSTM-125M [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks (7:1-ish).

d_ff=0: xLSTM blocks carry their own projections (mLSTM: up-projection 2x with
conv + matrix-memory cell; sLSTM: post-up-projection 4/3 gated FF). Recurrent
state instead of a KV cache => sub-quadratic, runs the long_500k cell.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    rope="none",
    norm="layernorm",
    slstm_at=(1, 7),
    tie_embeddings=True,
    subquadratic=True,
)
