"""Architecture and shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; input shapes are
``ShapeConfig``s. ``reduced()`` produces the tiny CPU-smoke variant of any
arch, preserving the family-specific structure (MoE stays MoE, hybrid stays
hybrid) while shrinking width/depth/vocab.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyper-params."""
    state_dim: int = 64          # N
    head_dim: int = 64           # P
    num_heads: int = 0           # derived if 0: d_inner // head_dim
    expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # derived if 0: d_model // num_heads
    # --- attention flavour ---
    rope: str = "full"           # full | half (chatglm 2d) | none (learned pos)
    sliding_window: int = 0      # 0 = full attention
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    mlp: str = "swiglu"          # swiglu | gelu
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    max_position_embeddings: int = 0   # only for learned-pos archs
    # --- family extensions ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    slstm_at: Tuple[int, ...] = ()         # xlstm: which blocks are sLSTM
    shared_attn_period: int = 0            # zamba2: shared attn every N slots
    encoder_layers: int = 0                # whisper: encoder depth
    encoder_seq: int = 0                   # whisper: # frame embeddings
    num_image_tokens: int = 0              # vlm: stubbed patch-embedding count
    # --- numerics ---
    dtype: str = "bfloat16"                # activation/compute dtype
    param_dtype: str = "bfloat16"
    opt_dtype: str = "float32"             # AdamW m/v dtype
    remat: bool = True
    # --- capability flags ---
    subquadratic: bool = False             # eligible for long_500k decode

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def adtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def is_encdec(self) -> bool:
        return self.family == "audio"

    def moe_inactive_ff_params(self) -> int:
        """Expert-FF params NOT active per token (for 6·N_active·D).

        Exact total param counts come from the abstract param pytree
        (``models.model.count_params``); this only supplies the MoE
        active/total correction, which is analytic by construction.
        """
        if not self.moe:
            return 0
        per_expert = 3 * self.d_model * self.d_ff
        return int(self.num_layers * per_expert
                   * (self.moe.num_experts - self.moe.top_k))

    def n_shared_applications(self) -> int:
        if not self.shared_attn_period:
            return 0
        return self.num_layers // self.shared_attn_period

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small_moe = MoEConfig(4, min(self.moe.top_k, 2)) if self.moe else None
        small_ssm = dataclasses.replace(
            self.ssm, state_dim=16, head_dim=16, chunk=16) if self.ssm else None
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=max(2, self.shared_attn_period + 1) if self.shared_attn_period else 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            sliding_window=min(self.sliding_window, 8) if self.sliding_window else 0,
            max_position_embeddings=min(self.max_position_embeddings, 128)
            if self.max_position_embeddings else 0,
            moe=small_moe,
            ssm=small_ssm,
            slstm_at=tuple(i for i in self.slstm_at if i < 2) or ((1,) if self.slstm_at else ()),
            shared_attn_period=min(self.shared_attn_period, 2) if self.shared_attn_period else 0,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
            num_image_tokens=min(self.num_image_tokens, 4) if self.num_image_tokens else 0,
            dtype="float32",
            param_dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode
    microbatch: int = 0          # train: grad-accum microbatch (0 = no accum)

    def with_microbatch(self, mb: int) -> "ShapeConfig":
        return dataclasses.replace(self, microbatch=mb)


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (see DESIGN §Arch-applicability)."""
    if shape.name == "long_500k" and not arch.subquadratic:
        return False, "pure full-attention arch: 500k dense KV decode skipped"
    return True, ""
