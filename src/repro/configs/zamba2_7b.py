"""Zamba2-7B [arXiv:2411.15242; unverified] — Mamba2 backbone + shared attn.

81 layer slots; every 6th slot applies ONE shared (weight-tied) transformer
block (attention + MLP), the rest are Mamba2 (SSD) blocks with state_dim=64.
SSM state + a handful of shared-attn KV caches => sub-quadratic, runs
long_500k.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    rope="full",
    norm="rmsnorm",
    mlp="swiglu",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=128),
    shared_attn_period=6,
    subquadratic=True,
)
