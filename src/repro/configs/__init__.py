"""Assigned architecture registry: ``get_arch(name)`` / ``ARCHS``."""
from repro.configs.base import (ArchConfig, MoEConfig, SSMConfig, ShapeConfig,
                                SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K,
                                LONG_500K, shape_applicable)
from repro.configs.starcoder2_7b import CONFIG as starcoder2_7b
from repro.configs.chatglm3_6b import CONFIG as chatglm3_6b
from repro.configs.llama3_2_3b import CONFIG as llama3_2_3b
from repro.configs.llama3_405b import CONFIG as llama3_405b
from repro.configs.whisper_base import CONFIG as whisper_base
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.granite_moe_1b import CONFIG as granite_moe_1b
from repro.configs.internvl2_26b import CONFIG as internvl2_26b
from repro.configs.xlstm_125m import CONFIG as xlstm_125m
from repro.configs.zamba2_7b import CONFIG as zamba2_7b

ARCHS = {c.name: c for c in (
    starcoder2_7b, chatglm3_6b, llama3_2_3b, llama3_405b, whisper_base,
    mixtral_8x7b, granite_moe_1b, internvl2_26b, xlstm_125m, zamba2_7b)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "ShapeConfig", "SHAPES",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "shape_applicable", "ARCHS", "get_arch"]
