"""Mixtral-8x7B [arXiv:2401.04088; hf] — MoE 8 experts top-2, SWA(4096).

Sliding-window attention bounds the KV cache, making the arch sub-quadratic
in context length => eligible for the long_500k decode cell.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope="full",
    sliding_window=4096,
    norm="rmsnorm",
    mlp="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2),
    subquadratic=True,            # via SWA-bounded KV cache
)
