"""Per-architecture sharding-policy resolution.

The production mesh is fixed at (data=16, model=16) per pod (+"pod" axis for
multi-pod). Which tensor dims can use the 16-wide "model" axis depends on
divisibility, so rules are resolved per arch:

  * attention: shard kv_heads if K % tp == 0, else the q-group dim if
    (H/K) % tp == 0, else run attention data-parallel (weights still FSDP).
    This mirrors Megatron practice where TP width is bounded by KV heads.
  * MoE: expert-parallel when E % tp == 0 (experts axis), else tensor-
    parallel inside experts (mlp axis).
  * vocab / mlp / ssm dims: sharded only when divisible.
"""
from __future__ import annotations

from typing import Dict, Optional

from jax.sharding import Mesh

from repro.configs.base import ArchConfig, SSMConfig
from repro.parallel.sharding import MeshAxes, ShardingPolicy


def _tp(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def policy_for(arch: ArchConfig, mesh: Optional[Mesh], *,
               fsdp: Optional[bool] = None,
               overrides: Optional[Dict[str, MeshAxes]] = None,
               seq_shard: bool = False,
               global_batch: Optional[int] = None) -> ShardingPolicy:
    tp = _tp(mesh) if mesh is not None else 1
    r: Dict[str, MeshAxes] = {}

    # batch sharding degrades gracefully for small batches (e.g. the
    # long_500k single-sequence decode): drop axes until divisible
    if global_batch is not None and mesh is not None:
        axes = ["pod", "data"] if "pod" in mesh.axis_names else ["data"]
        while axes:
            dp = 1
            for a in axes:
                dp *= mesh.shape[a]
            if global_batch % dp == 0:
                break
            axes.pop(0)                     # sacrifice the pod (DCI) axis first
        ba = tuple(axes) if axes else None
        r["batch"] = ba
        r["cache_batch"] = ba

    K, H = arch.num_kv_heads, arch.num_heads
    G = max(1, H // K)
    if K % tp == 0:
        r["kv_heads"], r["qgroup"] = "model", None
    elif G % tp == 0:
        r["kv_heads"], r["qgroup"] = None, "model"
    else:
        r["kv_heads"], r["qgroup"] = None, None

    if arch.moe is not None:
        if arch.moe.num_experts % tp == 0:
            r["experts"], r["mlp"] = "model", None
        else:
            r["experts"] = None
            r["mlp"] = "model" if arch.d_ff % tp == 0 else None
    else:
        r["mlp"] = "model" if (arch.d_ff and arch.d_ff % tp == 0) else None

    r["vocab"] = "model" if arch.vocab_size % tp == 0 else None

    s_cfg = arch.ssm or SSMConfig()
    d_inner_h = s_cfg.expand * arch.d_model               # hybrid
    d_inner_x = 2 * arch.d_model                           # xlstm mlstm
    di = d_inner_h if arch.family == "hybrid" else d_inner_x
    r["ssm_inner"] = "model" if di % tp == 0 else None
    nheads = (s_cfg.num_heads or di // s_cfg.head_dim) \
        if arch.family == "hybrid" else arch.num_heads
    r["ssm_heads"] = "model" if nheads % tp == 0 else None

    # sequence sharding of the residual stream (SP) — opt-in (perf knob)
    if seq_shard:
        r["act_seq"] = "model"

    if overrides:
        r.update(overrides)

    if fsdp is None:
        fsdp = False
    return ShardingPolicy(mesh, rules=r, fsdp=fsdp)


def default_fsdp(arch: ArchConfig, kind: str, tp: int = 16,
                 hbm_budget_bytes: float = 8e9) -> bool:
    """FSDP (ZeRO) when TP-only sharding of the persistent state would not
    fit the per-device HBM budget (v5e: 16 GB; ~8 GB left for state).

    train: params+grads+moments must fit; serve: bf16 params (+the cache,
    which is batch-sharded anyway) — weight-gathered serving is the standard
    fallback when a model exceeds its TP slice.
    """
    from repro.models.model import count_params
    p = count_params(arch)
    if kind == "train":
        moment_bytes = 2 if arch.opt_dtype == "bfloat16" else 4
        state_bytes = p * (2 + 2 + 2 * moment_bytes)   # params+grads+m+v
        return state_bytes / tp > hbm_budget_bytes
    return 2 * p / tp > 6e9
