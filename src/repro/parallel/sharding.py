"""Logical-axis sharding: MaxText-style rules mapping logical names to mesh axes.

Model code annotates activations with ``shard(x, "batch", "seq", "embed")``;
parameters carry logical-axis tuples recorded by ``ParamBuilder`` at init.
A ``ShardingPolicy`` resolves logical names to (possibly multiple) mesh axes.
Everything degrades to a no-op when no policy is active, so single-device
tests never touch mesh machinery.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# Baseline rule set: DP over (pod, data), Megatron TP over model, EP over model.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_seq": None,            # sequence axis of activations (SP shards this)
    "embed": None,              # residual-stream feature axis
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",             # d_ff
    "vocab": "model",
    "experts": "model",
    "expert_capacity": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_inner": "model",
    "conv_width": None,
    "layers": None,
    "fsdp": "data",             # extra axis FSDP shards params over
    "cache_batch": ("pod", "data"),
    "cache_seq": None,
    "cross_seq": None,
    "attn_q_seq": None,   # context-parallel attention: q rows over "model"
    "frames": None,
    "logit_seq": None,
}


class ShardingPolicy:
    """Resolves logical axis names to mesh axes; builds NamedShardings."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None,
                 fsdp: bool = False):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)
        self.fsdp = fsdp
        # Drop references to mesh axes the mesh does not actually have
        # (e.g. "pod" on the single-pod mesh).
        if mesh is not None:
            have = set(mesh.axis_names)
            clean = {}
            for k, v in self.rules.items():
                if v is None:
                    clean[k] = None
                elif isinstance(v, str):
                    clean[k] = v if v in have else None
                else:
                    kept = tuple(a for a in v if a in have)
                    clean[k] = kept if kept else None
            self.rules = clean

    def spec(self, *logical: Optional[str]) -> P:
        parts, used = [], set()
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = self.rules.get(name)
            if axes is None:
                parts.append(None)
            elif isinstance(axes, str):
                parts.append(None if axes in used else axes)
                used.add(axes)
            else:
                kept = tuple(a for a in axes if a not in used)
                used.update(kept)
                parts.append(kept if kept else None)
        return P(*parts)

    def named(self, *logical: Optional[str]) -> NamedSharding:
        assert self.mesh is not None
        return NamedSharding(self.mesh, self.spec(*logical))

    def param_spec(self, axes: Sequence[Optional[str]]) -> P:
        """Param sharding; with fsdp=True the largest unsharded dim also
        shards over the fsdp axis (applied later, needs shapes)."""
        return self.spec(*axes)

    def constraint(self, x, *logical: Optional[str]):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.named(*logical))


_state = threading.local()


def current_policy() -> Optional[ShardingPolicy]:
    return getattr(_state, "policy", None)


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    prev = getattr(_state, "policy", None)
    _state.policy = policy
    try:
        yield policy
    finally:
        _state.policy = prev


def shard(x, *logical: Optional[str]):
    """Annotate activation ``x`` with logical axes (no-op without a policy)."""
    pol = current_policy()
    if pol is None or pol.mesh is None:
        return x
    return pol.constraint(x, *logical)


def logical_spec(*logical: Optional[str]) -> Optional[P]:
    pol = current_policy()
    if pol is None:
        return None
    return pol.spec(*logical)


def fsdp_param_spec(policy: ShardingPolicy, axes: Tuple[Optional[str], ...],
                    shape: Tuple[int, ...]) -> P:
    """Resolve a parameter PartitionSpec, adding FSDP sharding of the largest
    still-unsharded, divisible dim over the fsdp axis."""
    spec = list(policy.spec(*axes))
    while len(spec) < len(shape):
        spec.append(None)
    if not policy.fsdp or policy.mesh is None:
        return P(*spec)
    fsdp_axes = policy.rules.get("fsdp")
    if fsdp_axes is None:
        return P(*spec)
    if isinstance(fsdp_axes, str):
        fsdp_axes = (fsdp_axes,)
    used = {a for s in spec if s for a in ((s,) if isinstance(s, str) else s)}
    fsdp_axes = tuple(a for a in fsdp_axes if a not in used)
    if not fsdp_axes:
        return P(*spec)
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= policy.mesh.shape[a]
    # pick the largest dim that is unsharded and divisible by the fsdp size;
    # never the scan-stacked "layers" dim (scan slices along it every step)
    cand = [(shape[i], i) for i in range(len(shape))
            if spec[i] is None and shape[i] % fsdp_size == 0
            and shape[i] >= fsdp_size
            and not (i < len(axes) and axes[i] == "layers")]
    if not cand:
        return P(*spec)
    _, idx = max(cand)
    spec[idx] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    return P(*spec)
