from repro.parallel.sharding import (ShardingPolicy, current_policy,
                                     use_policy, shard, logical_spec)

__all__ = ["ShardingPolicy", "current_policy", "use_policy", "shard",
           "logical_spec"]
