"""Fault tolerance: failure injection, checkpoint/restart supervision,
straggler detection.

The worker-pool model localizes failure handling (DESIGN §7): a dead worker
drains one pool and its in-flight task is re-queued; training jobs restart
from the latest checkpoint inside their pool instead of tearing down the
fleet. ``TrainSupervisor`` implements the restart loop for real training
processes (used by launch/train.py and the e2e tests); ``StragglerMonitor``
implements the EWMA-based detection used by both the supervisor and the
fleet simulator.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax

from repro.checkpoint import CheckpointManager, restore


class SimulatedFault(RuntimeError):
    pass


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault schedule: raise at the given global steps."""
    fail_at: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


class StragglerMonitor:
    """Per-worker EWMA step times; flags workers slower than
    ``factor`` x fleet median."""

    def __init__(self, alpha: float = 0.3, factor: float = 1.8,
                 min_samples: int = 3):
        self.alpha = alpha
        self.factor = factor
        self.min_samples = min_samples
        self.ewma: Dict[str, float] = {}
        self.count: Dict[str, int] = {}

    def record(self, worker: str, seconds: float):
        prev = self.ewma.get(worker)
        self.ewma[worker] = seconds if prev is None else \
            self.alpha * seconds + (1 - self.alpha) * prev
        self.count[worker] = self.count.get(worker, 0) + 1

    def median(self) -> Optional[float]:
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2] if vals else None

    def stragglers(self) -> List[str]:
        med = self.median()
        if med is None or med <= 0:
            return []
        return [w for w, v in self.ewma.items()
                if self.count.get(w, 0) >= self.min_samples
                and v > self.factor * med]


class TrainSupervisor:
    """Checkpoint/restart loop around a stateless step function.

    step_fn(state, step_idx) -> (state, metrics); data must be a pure
    function of step_idx (our pipeline is), so restarts are bit-exact.
    """

    def __init__(self, ckpt_dir: str, make_state: Callable[[], object],
                 step_fn: Callable, every: int = 20, keep: int = 2,
                 injector: Optional[FaultInjector] = None):
        self.mgr = CheckpointManager(ckpt_dir, keep=keep, every=every)
        self.make_state = make_state
        self.step_fn = step_fn
        self.injector = injector
        self.restarts = 0
        self.monitor = StragglerMonitor()

    def _resume(self):
        latest = self.mgr.latest()
        state = self.make_state()
        if latest is None:
            return state, 0
        state = restore(self.mgr.dir, latest, state)
        return state, latest

    def run(self, total_steps: int, max_restarts: int = 10):
        metrics_log = []
        while True:
            state, start = self._resume()
            step = start
            try:
                while step < total_steps:
                    if self.injector:
                        self.injector.check(step)
                    t0 = time.perf_counter()
                    state, metrics = self.step_fn(state, step)
                    jax.block_until_ready(
                        jax.tree.leaves(metrics)[0] if metrics else
                        jax.tree.leaves(state)[0])
                    self.monitor.record("self", time.perf_counter() - t0)
                    step += 1
                    metrics_log.append((step, metrics))
                    self.mgr.maybe_save(step, state)
                self.mgr.maybe_save(step, state, force=True)
                self.mgr.wait()
                return state, metrics_log, self.restarts
            except SimulatedFault:
                self.restarts += 1
                self.mgr.wait()
                if self.restarts > max_restarts:
                    raise
