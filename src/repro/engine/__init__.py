"""Layer B — the paper's worker-pool execution model mapped onto TPU mesh
slices: persistent compiled executables per (arch x step-kind) pool, queue-
driven proportional slice auto-scaling, fault tolerance and straggler
mitigation."""
from repro.engine.pools import (MLTask, SlicePoolExecutor, FleetSim,
                                CompileCostModel)
from repro.engine.fault_tolerance import (FaultInjector, StragglerMonitor,
                                          TrainSupervisor)

__all__ = ["MLTask", "SlicePoolExecutor", "FleetSim", "CompileCostModel",
           "FaultInjector", "StragglerMonitor", "TrainSupervisor"]
