"""Worker pools for ML workloads on TPU mesh slices (Layer B).

Two complementary realizations of the paper's execution models at ML scale:

1. ``FleetSim`` — fleet-scale discrete-event simulation, literally reusing
   Layer A's cluster/executors with TPU constants: a "node" is a mesh slice
   (gang of chips), a "pod creation" is XLA compilation + weight loading
   (measured compile times from the dry-run artifacts), and a task is a
   batch of train/serve steps whose duration comes from the roofline bound.
   The paper's result replays at fleet scale: per-task dispatch (job model)
   pays compile latency per task; persistent per-(arch x kind) worker pools
   amortize it and the proportional autoscaler splits slices between
   competing workloads.

2. ``SlicePoolExecutor`` — a *real* executor for this host: tiny (reduced)
   configs, actual jit compilation and execution; "job" mode clears the
   compile cache per task (cold dispatch), "pool" mode keeps per-pool
   executables hot. Used by examples/ and bench_ml_pools.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, get_arch
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.cluster import ClusterSim
from repro.core.engine import HyperflowEngine, RunReport
from repro.core.exec_models import JobExecutor, WorkerPoolExecutor
from repro.core.workflow import Workflow

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


# ------------------------------------------------------------ cost model ---

class CompileCostModel:
    """Step/compile costs per (arch, shape) from dry-run artifacts.

    step_seconds: roofline bound (kernelized) — the best-case wall step.
    compile_seconds: measured AOT compile wall time on this host (a proxy;
    the relative job-vs-pool comparison is what matters, as in the paper).
    """

    def __init__(self, art_dir: Path = ART):
        self.table: Dict[Tuple[str, str], Dict] = {}
        if Path(art_dir).exists():
            for f in Path(art_dir).glob("*_pod.json"):
                try:
                    d = json.loads(f.read_text())
                except ValueError:
                    continue
                if "skipped" in d or "error" in d:
                    continue
                self.table[(d["arch"], d["shape"])] = d

    def step_seconds(self, arch: str, shape: str) -> float:
        d = self.table.get((arch, shape))
        if d:
            return max(1e-3, d["bound_seconds_kernelized"])
        return 0.05

    def compile_seconds(self, arch: str, shape: str) -> float:
        d = self.table.get((arch, shape))
        if d:
            return max(1.0, d["compile_seconds"])
        return 10.0

    def weight_load_seconds(self, arch: str) -> float:
        """bf16 params fetched from checkpoint storage (~5 GB/s per slice)."""
        cfg = ARCHS.get(arch)
        if cfg is None:
            return 5.0
        from repro.models.model import count_params
        return max(1.0, 2 * count_params(cfg) / 5e9)


@dataclasses.dataclass
class MLTask:
    arch: str
    shape: str           # train_4k | prefill_32k | decode_32k | long_500k
    steps: int = 1

    @property
    def type(self) -> str:
        return f"{self.arch}:{self.shape}"


# ------------------------------------------------------------- FleetSim ----

class FleetSim:
    """Mixed train/serve fleet on n_slices mesh slices."""

    def __init__(self, n_slices: int = 16, seed: int = 0,
                 cost: Optional[CompileCostModel] = None):
        self.n_slices = n_slices
        self.seed = seed
        self.cost = cost or CompileCostModel()

    def workload(self, tasks: Sequence[MLTask],
                 chains: Sequence[Sequence[MLTask]] = ()) -> Workflow:
        """tasks: independent (serving bursts); chains: ordered (train jobs
        are sequential checkpoint segments)."""
        wf = Workflow("ml-fleet")
        for t in tasks:
            wf.add(t.type, t.steps * self.cost.step_seconds(t.arch, t.shape))
        for chain in chains:
            prev = None
            for t in chain:
                dur = t.steps * self.cost.step_seconds(t.arch, t.shape)
                prev = wf.add(t.type, dur,
                              deps=(prev,) if prev is not None else ())
        return wf

    def _sim(self, startup: float) -> ClusterSim:
        # one slice == one schedulable unit (cpu=1); compile+load = startup
        return ClusterSim(n_nodes=self.n_slices, node_cpu=1.0,
                          node_mem=1 << 40, seed=self.seed,
                          pod_startup=startup, backoff_initial=2.0,
                          backoff_max=30.0)

    def run(self, wf: Workflow, model: str = "worker_pools",
            compile_overhead: Optional[float] = None) -> RunReport:
        archs = {t.type.split(":")[0] for t in wf.tasks.values()}
        shapes = {t.type.split(":")[1] for t in wf.tasks.values()}
        # startup cost: compile + weight load for a representative pool
        mean_compile = sum(
            self.cost.compile_seconds(a, s) + self.cost.weight_load_seconds(a)
            for a in archs for s in shapes) / max(1, len(archs) * len(shapes))
        startup = compile_overhead if compile_overhead is not None \
            else mean_compile
        sim = self._sim(startup)
        if model == "job":
            executor = JobExecutor()
        elif model == "worker_pools":
            executor = WorkerPoolExecutor(job_headroom=0.0, sync_period=5.0,
                                          cooldown=15.0)
        else:
            raise ValueError(model)
        return HyperflowEngine(wf, executor, sim).run()


# ----------------------------------------------------- real executor -------

class SlicePoolExecutor:
    """Real execution of reduced-config steps on this host.

    mode="pool": one persistent jitted step per (arch x kind) — the worker-
    pool model. mode="job": jax compile caches are cleared before every
    task — per-task dispatch. The measured wall-clock difference is the
    paper's pod-creation overhead, reincarnated as XLA compilation.
    """

    def __init__(self, mode: str = "pool", seed: int = 0):
        assert mode in ("pool", "job")
        self.mode = mode
        self.seed = seed
        self._pools: Dict[Tuple[str, str], Dict] = {}
        self.compile_events: List[Tuple[str, float]] = []

    def _build(self, arch_name: str, kind: str) -> Dict:
        from repro.data import make_batch_fn
        from repro.launch.steps import init_train_state
        from repro.models import build_model
        from repro.optim import AdamWConfig

        cfg = get_arch(arch_name).reduced()
        model = build_model(cfg)
        t0 = time.perf_counter()
        if kind == "train":
            shape = ShapeConfig("tiny_train", 16, 4, "train")
            opt = AdamWConfig(moment_dtype="float32")
            state = init_train_state(model, jax.random.PRNGKey(self.seed), opt)
            batch_fn = make_batch_fn(cfg, shape, self.seed)

            from repro.optim import adamw_update

            @jax.jit
            def step(state, batch):
                (loss, _), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(state["params"], batch)
                new_p, new_o, stats = adamw_update(
                    state["params"], grads,
                    {"m": state["m"], "v": state["v"], "step": state["step"]},
                    opt)
                return ({"params": new_p, **new_o}, loss)

            state, loss = step(state, batch_fn(0))      # compile now
            jax.block_until_ready(loss)
            pool = {"cfg": cfg, "model": model, "state": state,
                    "step": step, "batch_fn": batch_fn, "kind": kind}
        else:
            B, S = 4, 16
            params = model.init(jax.random.PRNGKey(self.seed))
            cache = model.init_cache(B, S + 8, dtype=jnp.float32)
            prefill = jax.jit(model.prefill)
            decode = jax.jit(model.decode_step)
            toks = jnp.ones((B, S), jnp.int32)
            logits, cache = prefill(params, {"tokens": toks}, cache)
            logits, cache = decode(params, jnp.ones((B, 1), jnp.int32),
                                   cache, jnp.int32(S))
            jax.block_until_ready(logits)
            pool = {"cfg": cfg, "model": model, "params": params,
                    "cache": cache, "prefill": prefill, "decode": decode,
                    "kind": kind}
        self.compile_events.append(
            (f"{arch_name}:{kind}", time.perf_counter() - t0))
        return pool

    def run_task(self, arch_name: str, kind: str, steps: int = 2) -> Dict:
        t0 = time.perf_counter()
        key = (arch_name, kind)
        if self.mode == "job":
            jax.clear_caches()                  # cold dispatch, every task
            pool = self._build(arch_name, kind)
        else:
            pool = self._pools.get(key)
            if pool is None:
                pool = self._pools[key] = self._build(arch_name, kind)
        t_ready = time.perf_counter()
        if kind == "train":
            state = pool["state"]
            loss = None
            for i in range(steps):
                state, loss = pool["step"](state, pool["batch_fn"](i))
            jax.block_until_ready(loss)
            pool["state"] = state
            out = {"loss": float(loss)}
        else:
            params, cache = pool["params"], pool["cache"]
            tok = jnp.ones((4, 1), jnp.int32)
            logits = None
            for i in range(steps):
                logits, cache = pool["decode"](params, tok, cache,
                                               jnp.int32(16 + i))
            jax.block_until_ready(logits)
            out = {"logits_ok": bool(jnp.all(jnp.isfinite(logits)))}
        t1 = time.perf_counter()
        out.update({"setup_s": t_ready - t0, "run_s": t1 - t_ready,
                    "total_s": t1 - t0})
        return out
