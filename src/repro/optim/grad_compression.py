"""Int8 gradient compression with error feedback (optional distributed-
optimization feature).

Reduces the cross-pod (DCI) gradient all-reduce volume 4x (fp32 -> int8 with
a per-tensor fp32 scale). The quantization residual is carried in an error-
feedback buffer so the compressed SGD/AdamW iterates stay within O(1) of the
uncompressed trajectory (standard EF-SGD argument). Applied only across the
"pod" axis where link bandwidth is scarcest; intra-pod reductions stay fp32.

In this framework the hook wraps grads between accumulation and the
optimizer: quantize -> (all-reduce happens on the int8 view) -> dequantize,
with the residual added back next step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Pytree = object


def ef_init(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quant(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Pytree, error: Pytree) -> Tuple[Pytree, Pytree]:
    """Returns (dequantized grads as seen after the compressed all-reduce,
    new error buffers). The int8 round-trip models exactly what the wire
    carries; XLA sees int8 tensors at the collective boundary."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quant(x)
        d = _dequant(q, s)
        return d.astype(g.dtype), x - d

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
