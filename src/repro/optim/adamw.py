"""AdamW with decoupled weight decay, global-norm clipping and cosine LR —
pure pytree functions (moments dtype configurable: fp32 default, bf16 for
memory-bound giants like llama3-405b on v5e)."""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(1.0, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params, cfg: AdamWConfig) -> Dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt_state: Dict, cfg: AdamWConfig
                 ) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    lr = cosine_schedule(cfg, step)
    sf = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** sf
    bc2 = 1.0 - cfg.b2 ** sf
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gn, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, stats
