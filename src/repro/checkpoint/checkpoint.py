"""Checkpointing: per-leaf .npy shards + JSON manifest, async writer thread,
elastic restore (any mesh — shardings are applied at load via device_put).

Layout:
    <dir>/step_000120/
        manifest.json            # pytree structure + leaf paths + dtypes
        <flat-key>.npy           # one file per leaf
        _COMMITTED               # written last — incomplete dirs are ignored

The writer gathers to host (np.asarray) then hands the file I/O to a
background thread; ``wait()`` blocks (used before process exit and in
tests). Restore reads into host arrays and (optionally) device_puts with the
target sharding pytree — which is how elastic up/down-scaling reshapes a
run: the same checkpoint restores onto any mesh.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

Pytree = Any
_SEP = "::"


def _flatten(tree: Pytree) -> Dict[str, Any]:
    flat = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(v, path + (str(i),))
        else:
            flat[_SEP.join(path)] = node
    rec(tree, ())
    return flat


def _unflatten_into(template: Pytree, flat: Dict[str, Any]) -> Pytree:
    def rec(node, path):
        if isinstance(node, dict):
            return {k: rec(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [rec(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t)
        return flat[_SEP.join(path)]
    return rec(template, ())


def save(ckpt_dir: str | Path, step: int, tree: Pytree,
         blocking: bool = True) -> Path:
    ckpt_dir = Path(ckpt_dir)
    out = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}_{os.getpid()}_{threading.get_ident()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    host, dtypes = {}, {}
    for k, v in flat.items():
        dt = str(jnp.asarray(v).dtype) if not isinstance(v, np.ndarray) \
            else str(v.dtype)
        dtypes[k] = dt
        a = np.asarray(v, np.float32) if dt == "bfloat16" else np.asarray(v)
        host[k] = a

    def write():
        manifest = {}
        for k, v in host.items():
            fn = re.sub(r"[^\w.\-]", "_", k) + ".npy"
            np.save(tmp / fn, v)
            manifest[k] = {"file": fn, "dtype": dtypes[k],
                           "shape": list(v.shape)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "_COMMITTED").write_text("ok")
        if out.exists():            # concurrent writer won the race — fine
            shutil.rmtree(tmp)
            return
        try:
            os.rename(tmp, out)
        except OSError:
            shutil.rmtree(tmp, ignore_errors=True)

    if blocking:
        write()
        return out
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return out


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for p in ckpt_dir.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "_COMMITTED").exists():
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str | Path, step: int, template: Pytree,
            shardings: Optional[Pytree] = None) -> Pytree:
    """Restore into the structure of ``template``; device_put with
    ``shardings`` (same structure) if given — this is the elastic-resharding
    path: any mesh may load any checkpoint."""
    src = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())
    flat_t = _flatten(template)
    flat = {}
    for k in flat_t:
        meta = manifest[k]
        a = np.load(src / meta["file"])
        if meta["dtype"] == "bfloat16":
            a = jnp.asarray(a, jnp.bfloat16)
        flat[k] = a
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree,
                            shardings)
    return tree


class CheckpointManager:
    """Async rolling checkpointer with a retention budget."""

    def __init__(self, ckpt_dir: str | Path, keep: int = 3,
                 every: int = 50):
        self.dir = Path(ckpt_dir)
        self.keep = keep
        self.every = every
        self._threads = []
        self._saved_steps = set()

    def maybe_save(self, step: int, tree: Pytree, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        if step in self._saved_steps:
            return False
        self._saved_steps.add(step)
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}

        def write():
            save(self.dir, step, host, blocking=True)
            self._gc()

        t = threading.Thread(target=write, daemon=True)
        t.start()
        self._threads.append(t)
        return True

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for p in self.dir.iterdir()
            if (m := re.fullmatch(r"step_(\d+)", p.name))
            and (p / "_COMMITTED").exists())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self):
        for t in self._threads:
            t.join()
        self._threads = []

    def latest(self) -> Optional[int]:
        return latest_step(self.dir)
