"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_total   / (chips x peak_FLOP/s)
    memory term     = HLO_bytes_total   / (chips x HBM_bw)
    collective term = collective_bytes  / (chips x link_bw)

``cost_analysis()`` on an SPMD-partitioned executable reports *per-device*
flops/bytes; we multiply by chip count so the spec formulas above apply
verbatim. Collective bytes are summed over the operands of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
in the partitioned HLO (per-device shard sizes, x chips). We additionally
report a ring-model estimate (per-op factor x bytes / link_bw) which is the
better wall-clock predictor; both appear in EXPERIMENTS.md.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeConfig

HW = {
    "peak_flops": 197e12,       # bf16 per chip
    "hbm_bw": 819e9,            # bytes/s per chip
    "link_bw": 50e9,            # bytes/s per ICI link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ring-model cost factors: per-device link-bytes per operand byte
_RING_FACTOR = {
    "all-reduce": 2.0,          # 2(N-1)/N ~ 2
    "all-gather": None,         # (N-1) x shard bytes — needs N
    "reduce-scatter": 1.0,      # (N-1)/N ~ 1
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _type_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * b


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


_OP_LINE_RE = re.compile(
    r"=\s+(?P<result>.+?)\s+(?P<kind>all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute)\(")


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Dict]:
    """Per-collective-kind OPERAND bytes + ring-model link bytes (per device).

    The optimized-HLO printer types only the *result*, so operand bytes are
    derived per kind: AR/A2A/permute results equal their operands;
    all-gather operands are result/N shards; reduce-scatter operands are
    result x N. (Sync ops only — the CPU dry-run backend does not emit
    -start/-done pairs.)
    """
    out = {k: {"count": 0, "bytes": 0.0, "ring_bytes": 0.0}
           for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        res_bytes = sum(_type_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(m.group("result")))
        n = max(2, _group_size(line, n_devices))
        if kind == "all-gather":
            op_bytes = res_bytes / n
            ring = (n - 1) * op_bytes                  # ~= res_bytes
        elif kind == "reduce-scatter":
            op_bytes = res_bytes * n
            ring = (n - 1) * res_bytes
        elif kind == "all-reduce":
            op_bytes = res_bytes
            ring = 2.0 * (n - 1) / n * op_bytes
        else:                                          # all-to-all / permute
            op_bytes = res_bytes
            ring = (n - 1) / n * op_bytes
        out[kind]["count"] += 1
        out[kind]["bytes"] += op_bytes
        out[kind]["ring_bytes"] += ring
    return out


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    ring_bytes_per_device: float
    collectives: Dict[str, Dict]
    memory: Dict[str, float]
    model_flops_total: float
    compile_seconds: float = 0.0
    # scope-bucketed costs (per device) + the Pallas-kernel traffic model
    bytes_by_scope: Dict[str, float] = dataclasses.field(default_factory=dict)
    flops_by_scope: Dict[str, float] = dataclasses.field(default_factory=dict)
    kernel_min_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    causal_factor: float = 1.0
    f32_act_ring: float = 0.0    # CPU float-norm inflation (see hlo_cost)

    # --- roofline terms (seconds) ---
    @property
    def compute_term(self) -> float:
        return self.flops_per_device / HW["peak_flops"]

    @property
    def memory_term(self) -> float:
        return self.bytes_per_device / HW["hbm_bw"]

    @property
    def collective_term(self) -> float:
        return self.collective_bytes_per_device / HW["link_bw"]

    @property
    def collective_term_ring(self) -> float:
        """TPU-adjusted ring model: f32 collectives on dot-adjacent
        activations are a CPU float-normalization artifact — the TPU
        program moves them in bf16 (half the bytes)."""
        adj = self.ring_bytes_per_device - 0.5 * self.f32_act_ring
        return adj / HW["link_bw"]

    @property
    def collective_term_ring_raw(self) -> float:
        return self.ring_bytes_per_device / HW["link_bw"]

    # --- Pallas-kernelized terms: attention/ssd/mlstm interiors live in
    # VMEM on the TPU target; their HBM traffic drops to the analytic tile
    # I/O minimum and flash skips fully-masked blocks ---
    @property
    def kernel_scope_bytes(self) -> float:
        return sum(v for k, v in self.bytes_by_scope.items() if k != "other")

    @property
    def bytes_kernelized(self) -> float:
        return (self.bytes_per_device - self.kernel_scope_bytes
                + sum(self.kernel_min_bytes.values()))

    @property
    def flops_kernelized(self) -> float:
        attn = sum(v for k, v in self.flops_by_scope.items()
                   if "attention" in k)
        return self.flops_per_device - attn * (1.0 - self.causal_factor)

    @property
    def memory_term_kernelized(self) -> float:
        return self.bytes_kernelized / HW["hbm_bw"]

    @property
    def compute_term_kernelized(self) -> float:
        return self.flops_kernelized / HW["peak_flops"]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_term_kernelized,
                 "memory": self.memory_term_kernelized,
                 "collective": self.collective_term_ring}
        return max(terms, key=terms.get)

    @property
    def bound_seconds(self) -> float:
        """XLA-fallback bound (what compiles in this container)."""
        return max(self.compute_term, self.memory_term,
                   self.collective_term_ring)

    @property
    def bound_seconds_kernelized(self) -> float:
        """TPU-target bound (Pallas kernels for the tagged interiors)."""
        return max(self.compute_term_kernelized,
                   self.memory_term_kernelized,
                   self.collective_term_ring)

    @property
    def useful_flops_fraction(self) -> float:
        total_hlo = self.flops_kernelized * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful-compute-time / kernelized bound — the score we hillclimb."""
        useful = self.model_flops_total / (self.chips * HW["peak_flops"])
        return useful / self.bound_seconds_kernelized \
            if self.bound_seconds_kernelized else 0.0

    @property
    def roofline_fraction_xla(self) -> float:
        useful = self.model_flops_total / (self.chips * HW["peak_flops"])
        return useful / self.bound_seconds if self.bound_seconds else 0.0

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        for k in ("compute_term", "memory_term", "collective_term",
                  "collective_term_ring", "dominant", "bound_seconds",
                  "useful_flops_fraction", "roofline_fraction",
                  "compute_term_kernelized", "memory_term_kernelized",
                  "bound_seconds_kernelized", "roofline_fraction_xla",
                  "bytes_kernelized", "flops_kernelized"):
            d[k] = getattr(self, k)
        return d


def analyze_compiled(compiled, *, arch: ArchConfig, shape: ShapeConfig,
                     mesh_name: str, chips: int,
                     compile_seconds: float = 0.0,
                     policy=None, cache_bytes: int = 2) -> CellReport:
    from repro.roofline.hlo_cost import analyze_hlo
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    try:
        ma = compiled.memory_analysis()
        memory = {
            "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
            "peak_bytes_est": float(getattr(ma, "argument_size_in_bytes", 0))
            + float(getattr(ma, "output_size_in_bytes", 0))
            + float(getattr(ma, "temp_size_in_bytes", 0))
            - float(getattr(ma, "alias_size_in_bytes", 0)),
        }
    except Exception as e:                     # CPU backend may not support
        memory = {"error": 0.0}
    text = compiled.as_text()
    hc = analyze_hlo(text, chips)
    memory["xla_flops"] = float(cost.get("flops", 0.0))
    memory["xla_bytes"] = float(cost.get("bytes accessed", 0.0))
    memory["unknown_trip_loops"] = float(hc.n_unknown_trip)
    kv_seq_shards = 1
    if policy is not None and policy.mesh is not None:
        ax = policy.rules.get("cache_seq")
        if ax:
            ax = (ax,) if isinstance(ax, str) else ax
            for a in ax:
                kv_seq_shards *= policy.mesh.shape[a]
    kmin, causal = kernel_traffic(arch, shape, chips, hc.bytes_by_scope,
                                  kv_seq_shards=kv_seq_shards,
                                  cache_bytes=cache_bytes)
    return CellReport(
        arch=arch.name, shape=shape.name, mesh=mesh_name, chips=chips,
        flops_per_device=hc.flops, bytes_per_device=hc.bytes_accessed,
        collective_bytes_per_device=hc.collective_bytes,
        ring_bytes_per_device=hc.ring_bytes,
        collectives=hc.collectives, memory=memory,
        model_flops_total=model_flops(arch, shape),
        compile_seconds=compile_seconds,
        bytes_by_scope=hc.bytes_by_scope, flops_by_scope=hc.flops_by_scope,
        kernel_min_bytes=kmin, causal_factor=causal,
        f32_act_ring=hc.f32_act_ring)


def kernel_traffic(arch: ArchConfig, shape: ShapeConfig, chips: int,
                   bytes_by_scope: Dict[str, float],
                   block_q: int = 512,
                   kv_seq_shards: int = 1,
                   cache_bytes: int = 2) -> Tuple[Dict[str, float], float]:
    """Analytic minimum HBM traffic (bytes/device) for the Pallas-kernelized
    interiors, and the flash causal block-skip factor.

    flash fwd: q + out read/written once; k,v streamed once per q-block row
    -> traffic = (q + o) + nq*(k + v); train adds ~2x for the backward
    (dq/dk/dv passes re-stream the same tiles). ssd/mlstm kernels: chunk
    intermediates stay in VMEM; surface = block in/out (~3x inner width).
    Replication note: if attention is unsharded on "model", every model rank
    streams the same tiles, so per-device traffic does not shrink — exactly
    what the fallback shows too.
    """
    B, S = shape.global_batch, shape.seq_len
    dp = max(1, chips // 16)                 # batch shard width
    B_loc = max(1, B // dp)
    H, K, hd = arch.num_heads, arch.num_kv_heads, arch.hd
    by = 2                                    # bf16
    mult = 3.0 if shape.kind == "train" else 1.0

    n_attn = (arch.num_layers if arch.family in ("dense", "moe", "vlm")
              else arch.num_layers + arch.encoder_layers if arch.family == "audio"
              else (arch.num_layers // arch.shared_attn_period
                    if arch.shared_attn_period else 0))
    out: Dict[str, float] = {}
    causal = 1.0
    if "flash_attention" in bytes_by_scope or "dense_attention" in bytes_by_scope:
        if shape.kind == "decode":
            ctx = min(S, arch.sliding_window) if arch.sliding_window else S
            ctx = ctx // max(1, kv_seq_shards)   # sequence-sharded cache
            per_layer = B_loc * ctx * K * hd * cache_bytes * 2   # k and v
        else:
            nq = max(1, S // block_q)
            q = B_loc * S * H * hd * by
            o = q
            kv = B_loc * S * K * hd * by * 2
            # causal: q-block i streams only i+1 kv blocks -> ~nq/2 effective
            eff_nq = (nq + 1) / 2 if not arch.sliding_window else \
                min(nq, arch.sliding_window // block_q + 1)
            per_layer = (q + o) + eff_nq * kv
            causal = 0.5 + 0.5 / nq
            if arch.sliding_window and arch.sliding_window < S:
                causal = min(1.0, arch.sliding_window / S + 1.0 / nq)
        scope = ("flash_attention" if "flash_attention" in bytes_by_scope
                 else "dense_attention")
        out[scope] = n_attn * per_layer * mult
    if "ssd_chunk" in bytes_by_scope:
        s_cfg = arch.ssm
        di = (s_cfg.expand if s_cfg else 2) * arch.d_model
        n_mamba = arch.num_layers - (arch.num_layers // arch.shared_attn_period
                                     if arch.shared_attn_period else 0)
        out["ssd_chunk"] = n_mamba * 3 * B_loc * S * di * by * mult
    if "mlstm_cell" in bytes_by_scope:
        di = 2 * arch.d_model
        n_m = arch.num_layers - len(arch.slstm_at)
        out["mlstm_cell"] = n_m * 4 * B_loc * S * di * by * mult
    if "moe_dispatch" in bytes_by_scope and arch.moe is not None:
        # fused dispatch kernel: one write + two reads of the (per-shard)
        # combine tensor; index arithmetic stays in VMEM/registers.
        # decode processes ONE token per step, not seq_len.
        import math as _m
        E, kk = arch.moe.num_experts, arch.moe.top_k
        s_tok = 1 if shape.kind == "decode" else S
        Cap = max(8, ((int(_m.ceil(s_tok * kk * arch.moe.capacity_factor
                                   / E)) + 7) // 8) * 8)
        e_shards = min(16, E) if E % 16 == 0 else 1
        out["moe_dispatch"] = (arch.num_layers * 3 * B_loc * s_tok
                               * (E // e_shards) * Cap * by * mult)
    if "kv_cache_update" in bytes_by_scope:
        # in-place DUS on the donated cache: write (and RAW-read) only the
        # updated token slots; the full-buffer convert churn around it is a
        # CPU float-normalization artifact (TPU reads bf16/int8 natively)
        wrote = S if shape.kind != "decode" else 1
        wrote = min(wrote, arch.sliding_window) if arch.sliding_window else wrote
        out["kv_cache_update"] = (n_attn * 2 * B_loc * wrote * K * hd
                                  * cache_bytes * 2)         # k and v
    return out, causal


def model_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    """Useful model FLOPs for the whole step: 6·N·D (train) / 2·N·D
    (prefill/decode), N = active non-embedding params, plus explicit
    attention (context) FLOPs."""
    from repro.models.model import count_params
    n = count_params(arch)
    n -= arch.moe_inactive_ff_params()
    if not arch.tie_embeddings:
        n -= arch.vocab_size * arch.d_model      # input table (lookup, no FLOPs)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens, mult = B * S, 6
    elif shape.kind == "prefill":
        tokens, mult = B * S, 2
    else:
        tokens, mult = B, 2
    param_flops = mult * n * tokens

    # attention context FLOPs: 2 matmuls (QK^T, PV) of 2*S_ctx*H*hd per token
    H, hd = arch.num_heads, arch.hd
    n_attn_layers = (arch.num_layers if arch.family in
                     ("dense", "moe", "vlm", "audio")
                     else (arch.num_layers // arch.shared_attn_period
                           if arch.shared_attn_period else 0))
    if shape.kind == "decode":
        ctx = min(S, arch.sliding_window) if arch.sliding_window else S
        attn = 4 * B * ctx * H * hd * n_attn_layers
    else:
        ctx = S
        causal = 0.5
        if arch.sliding_window and arch.sliding_window < S:
            causal = arch.sliding_window / S      # banded
        attn = 4 * B * S * ctx * causal * H * hd * n_attn_layers
        attn *= 3 if shape.kind == "train" else 1
    return float(param_flops + attn)
