from repro.roofline.analysis import (HW, CellReport, analyze_compiled,
                                     model_flops, parse_collectives)

__all__ = ["HW", "CellReport", "analyze_compiled", "model_flops",
           "parse_collectives"]
