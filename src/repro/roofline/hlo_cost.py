"""Trip-count-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's built-in ``cost_analysis()`` counts ``while`` bodies ONCE, which
undercounts scanned programs (layer scans, grad-accumulation scans, flash
attention block scans) by orders of magnitude. This analyzer walks the call
graph from ENTRY with loop-trip multipliers and accumulates:

  * flops            — from ``dot`` result/contraction shapes,
  * bytes accessed   — a fused-memory-traffic model: per instruction,
                       result + operand bytes, with slicing ops counted at
                       slice (not operand) size; fusions count only their
                       surface operands/results (interior is fused),
  * collective bytes — per kind, with ring-model link bytes.

Trip counts come from ``backend_config={"known_trip_count":{"n":N}}`` when
present, else the largest integer constant in the loop condition
computation (the jax scan pattern), else 1 with a warning.

All numbers are per-device (the input is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLED_RE = re.compile(
    r"(?:condition|body|calls|to_apply|branch_computations)=\{?%?([\w.\-]+)"
    r"(?:,\s*%?([\w.\-]+))*")
_TRIP_RE = re.compile(r'known_trip_count[\\\"={:]+n[\\\"]*[:=][\\\"]*(\d+)')
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _shape_dims(typestr: str) -> List[List[int]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt in _DTYPE_BYTES:
            out.append([int(d) for d in dims.split(",") if d])
    return out


@dataclasses.dataclass
class Instr:
    name: str
    typestr: str
    op: str
    rest: str
    operands: List[str]
    result_bytes: int
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    table: Dict[str, Instr]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, typestr, op, rest = m.groups()
        # operands: %refs inside the call parens (up to the closing paren
        # at depth 0 — approximate by cutting at '), ' attr boundary)
        call = rest.split("), ")[0]
        operands = _OPERAND_RE.findall(call)
        ins = Instr(name, typestr, op, rest, operands, _shape_bytes(typestr),
                    is_root=line.lstrip().startswith("ROOT"))
        cur.instrs.append(ins)
        cur.table[name] = ins
    return comps


def _trip_count(instr: Instr, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(instr.rest)
    if m:
        return int(m.group(1))
    mc = re.search(r"condition=%?([\w.\-]+)", instr.rest)
    if mc and mc.group(1) in comps:
        consts = []
        for i in comps[mc.group(1)].instrs:
            if i.op == "constant":
                m = re.match(r"(\d+)\)", i.rest)
                if m:
                    consts.append(int(m.group(1)))
            consts.extend(int(c) for c in _CONST_RE.findall(i.rest))
        if consts:
            return max(consts)
    return 1


def _dot_flops(instr: Instr, comp: Computation) -> float:
    dims = _shape_dims(instr.typestr)
    if not dims:
        return 0.0
    out_n = 1
    for d in dims[0]:
        out_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contracted = 1
    if m and instr.operands:
        lhs = comp.table.get(instr.operands[0])
        if lhs is not None:
            ldims = _shape_dims(lhs.typestr)
            if ldims:
                for idx in m.group(1).split(","):
                    if idx and int(idx) < len(ldims[0]):
                        contracted *= ldims[0][int(idx)]
    return 2.0 * out_n * contracted


_FREE_OPS = {"tuple", "get-tuple-element", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "custom-call"}
_SLICE_OPS = {"dynamic-slice", "dynamic-update-slice", "gather", "scatter",
              "slice"}
_PARAM_IDX_RE = re.compile(r"^(\d+)\)")


def _fusion_bytes(ins: Instr, comp: Computation,
                  comps: Dict[str, Computation]) -> float:
    """HBM traffic of a fusion node: slice-aware.

    Loop bodies pass whole scan-stacked arrays into fusions that slice them
    interiorly — counting full operand bytes would overcount by the layer
    count. For each fusion parameter consumed ONLY by slicing ops, charge the
    slice results instead of the full array; if the fusion root is a
    dynamic-update-slice, charge the update size (the buffer aliases).
    """
    mc = re.search(r"calls=%?([\w.\-]+)", ins.rest)
    fc = comps.get(mc.group(1)) if mc else None
    if fc is None:
        return ins.result_bytes + _operand_bytes(ins, comp)

    # map parameter index -> param instr name & bytes
    params = {}
    for fi in fc.instrs:
        if fi.op == "parameter":
            m = _PARAM_IDX_RE.match(fi.rest)
            if m:
                params[int(m.group(1))] = fi

    def real_consumers(name, depth=0):
        """Consumers, looking through bitcast/reshape/copy views."""
        out = []
        for fj in fc.instrs:
            if name in fj.operands:
                if fj.op in ("bitcast", "reshape", "copy") and depth < 3:
                    out.extend(real_consumers(fj.name, depth + 1))
                else:
                    out.append(fj)
        return out

    read = 0.0
    for idx, opnd in enumerate(ins.operands):
        d = comp.table.get(opnd)
        full = d.result_bytes if d is not None else 0
        pi = params.get(idx)
        if pi is None:
            read += full
            continue
        consumers = real_consumers(pi.name)
        if consumers and all(c.op in ("dynamic-slice", "gather", "slice",
                                      "dynamic-update-slice")
                             for c in consumers):
            sliced = 0.0
            for c in consumers:
                if c.op == "dynamic-update-slice":
                    # aliased buffer: written portion only
                    if len(c.operands) >= 2:
                        u = fc.table.get(c.operands[1])
                        sliced += u.result_bytes if u is not None else 0
                else:
                    sliced += c.result_bytes
            read += min(full, sliced) if sliced else full
        else:
            read += full

    # root write size: DUS roots alias their big operand
    write = ins.result_bytes
    root = next((fi for fi in fc.instrs if fi.is_root),
                fc.instrs[-1] if fc.instrs else None)
    while root is not None and root.op in ("bitcast", "reshape", "copy") \
            and root.operands:
        root = fc.table.get(root.operands[0])
    if root is not None and root.op == "dynamic-update-slice" \
            and len(root.operands) >= 2:
        u = fc.table.get(root.operands[1])
        if u is not None:
            write = u.result_bytes
    return read + write


SCOPES = ("flash_attention", "dense_attention", "mlstm_cell", "ssd_chunk",
          "kv_cache_update", "moe_dispatch")
_META_RE = re.compile(r'op_name="([^"]*)"')


def _scope_of(rest: str) -> Optional[str]:
    m = _META_RE.search(rest)
    if not m:
        return None
    name = m.group(1)
    for s in SCOPES:
        if s in name:
            return s
    return None


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collectives: Dict[str, Dict] = dataclasses.field(
        default_factory=lambda: {k: {"count": 0, "bytes": 0.0,
                                     "ring_bytes": 0.0} for k in COLLECTIVES})
    n_unknown_trip: int = 0
    dot_calls: float = 0.0
    bytes_by_scope: Dict[str, float] = dataclasses.field(default_factory=dict)
    flops_by_scope: Dict[str, float] = dataclasses.field(default_factory=dict)
    # ring bytes of f32 collectives on dot-adjacent activations: CPU float-
    # normalization upcasts bf16 dots (TPU moves these in bf16 — half)
    f32_act_ring: float = 0.0

    def _add_scoped(self, table: Dict[str, float], scope: Optional[str],
                    val: float):
        key = scope or "other"
        table[key] = table.get(key, 0.0) + val

    @property
    def collective_bytes(self) -> float:
        return sum(c["bytes"] for c in self.collectives.values())

    @property
    def ring_bytes(self) -> float:
        return sum(c["ring_bytes"] for c in self.collectives.values())


_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_ITOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return default


def _operand_bytes(instr: Instr, comp: Computation) -> float:
    tot = 0.0
    for o in instr.operands:
        d = comp.table.get(o)
        if d is not None:
            tot += d.result_bytes
    return tot


def analyze_hlo(text: str, n_devices: int) -> HloCost:
    comps = parse_module(text)
    # ENTRY computation: the one whose name contains "main" — fall back to
    # the one not referenced by any other computation
    referenced = set()
    for c in comps.values():
        for i in c.instrs:
            for m in _CALLED_RE.finditer(i.rest):
                referenced.update(g for g in m.groups() if g)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None:
        cands = [n for n in comps if n not in referenced]
        entry = cands[0] if cands else next(iter(comps))

    cost = HloCost()
    seen_stack = []

    def visit(comp_name: str, mult: float, flops_only: bool):
        comp = comps.get(comp_name)
        if comp is None or comp_name in seen_stack:
            return
        seen_stack.append(comp_name)
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                trip = _trip_count(ins, comps)
                if trip == 1:
                    cost.n_unknown_trip += 1
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                if mb:
                    visit(mb.group(1), mult * trip, flops_only)
                continue
            if op in ("call", "conditional", "async-start"):
                for m in _CALLED_RE.finditer(ins.rest):
                    for g in m.groups():
                        if g:
                            visit(g, mult, flops_only)
                continue
            if op == "fusion":
                # slice-aware surface bytes; interior visited for dot flops
                # only (fused interior doesn't touch HBM)
                mc = re.search(r"calls=%?([\w.\-]+)", ins.rest)
                if not flops_only:
                    b = mult * _fusion_bytes(ins, comp, comps)
                    cost.bytes_accessed += b
                    scope = _scope_of(ins.rest)
                    if scope is None and mc and mc.group(1) in comps:
                        # late-created wrapper fusions lose op_name — fall
                        # back to any interior instruction's metadata
                        for fi in comps[mc.group(1)].instrs:
                            scope = _scope_of(fi.rest)
                            if scope:
                                break
                    if scope is None:
                        # inherit from a defining operand or a consumer
                        # (float-normalization converts of big carried
                        # buffers lose their metadata entirely)
                        for o in ins.operands:
                            d = comp.table.get(o)
                            if d is not None:
                                scope = _scope_of(d.rest)
                                if scope:
                                    break
                    if scope is None:
                        for other in comp.instrs:
                            if ins.name in other.operands:
                                scope = _scope_of(other.rest)
                                if scope:
                                    break
                    cost._add_scoped(cost.bytes_by_scope, scope, b)
                if mc:
                    visit(mc.group(1), mult, True)
                continue
            if op == "dot":
                f = mult * _dot_flops(ins, comp)
                cost.flops += f
                cost.dot_calls += mult
                cost._add_scoped(cost.flops_by_scope, _scope_of(ins.rest), f)
                if not flops_only:
                    b = mult * (ins.result_bytes + _operand_bytes(ins, comp))
                    cost.bytes_accessed += b
                    cost._add_scoped(cost.bytes_by_scope,
                                     _scope_of(ins.rest), b)
                continue
            if flops_only:
                continue
            if op in COLLECTIVES or any(
                    op == k + "-start" for k in COLLECTIVES):
                kind = op.replace("-start", "")
                res = ins.result_bytes
                n = max(2, _group_size(ins.rest, n_devices))
                if kind == "all-gather":
                    opb = res / n
                    ring = (n - 1) * opb
                elif kind == "reduce-scatter":
                    opb = res * n
                    ring = (n - 1) * res
                elif kind == "all-reduce":
                    opb = res
                    ring = 2.0 * (n - 1) / n * opb
                else:
                    opb = res
                    ring = (n - 1) / n * opb
                c = cost.collectives[kind]
                c["count"] += mult
                c["bytes"] += mult * opb
                c["ring_bytes"] += mult * ring
                cost.bytes_accessed += mult * res
                meta = _META_RE.search(ins.rest)
                if "f32[" in ins.typestr and meta and (
                        "dot_general" in meta.group(1)
                        or "rematted" in meta.group(1)):
                    cost.f32_act_ring += mult * ring
                continue
            if op in _FREE_OPS:
                continue
            if op in _SLICE_OPS:
                upd = ins.result_bytes
                if op == "dynamic-update-slice" and len(ins.operands) >= 2:
                    u = comp.table.get(ins.operands[1])
                    if u is not None:
                        upd = u.result_bytes
                b = mult * 2 * upd
                cost.bytes_accessed += b
                cost._add_scoped(cost.bytes_by_scope, _scope_of(ins.rest), b)
                continue
            b = mult * (ins.result_bytes + _operand_bytes(ins, comp))
            cost.bytes_accessed += b
            cost._add_scoped(cost.bytes_by_scope, _scope_of(ins.rest), b)
        seen_stack.pop()

    visit(entry, 1.0, False)
    return cost
