"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, inherently sequential scan).

TPU adaptation notes (DESIGN §Hardware-adaptation): the original xLSTM ships
fused CUDA kernels for both cells. The mLSTM parallel form maps naturally to
MXU matmuls — we use a chunkwise decomposition (intra-chunk D-masked
attention-like matmuls + inter-chunk (C, n, m) recurrence) mirroring our SSD
schedule. The sLSTM recurrence is sequential by construction (the paper says
as much); it lowers to ``lax.scan`` over time with per-head block-diagonal
recurrent matmuls — no TPU-parallel form exists, so xlstm-125m keeps sLSTM at
only the configured block positions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import norm, init_norm
from repro.models.ssm import _depthwise_conv
from repro.parallel.sharding import shard

NEG = -1e30


def _logsigmoid(x):
    return -jax.nn.softplus(-x)


# ------------------------------------------------------------- mLSTM -------

@jax.named_scope("mlstm_cell")
def mlstm_chunked(q, k, v, i_raw, f_raw, chunk: int, carry=None):
    """Chunkwise stabilized mLSTM cell.

    q,k,v: (B,S,H,D); i_raw,f_raw: (B,S,H). carry: None or (C,n,m) with
    C (B,H,D,D), n (B,H,D), m (B,H). Returns (h (B,S,H,D), carry').
    """
    B, S, H, D = q.shape
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    scale = D ** -0.5
    qc = q.reshape(B, nc, Q, H, D).transpose(0, 3, 1, 2, 4)    # (B,H,nc,Q,D)
    kc = k.reshape(B, nc, Q, H, D).transpose(0, 3, 1, 2, 4) * scale
    vc = v.reshape(B, nc, Q, H, D).transpose(0, 3, 1, 2, 4)
    ic = i_raw.reshape(B, nc, Q, H).transpose(0, 3, 1, 2)      # (B,H,nc,Q)
    lf = _logsigmoid(f_raw.astype(jnp.float32))
    fc = lf.reshape(B, nc, Q, H).transpose(0, 3, 1, 2)
    F = jnp.cumsum(fc, axis=-1)                                # (B,H,nc,Q)
    ic = ic.astype(jnp.float32)

    # intra-chunk log-decay matrix: logD[l,s] = F_l - F_s + i_s (s <= l)
    logD = F[..., :, None] - F[..., None, :] + ic[..., None, :]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    logD = jnp.where(tri, logD, NEG)

    if carry is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), NEG, jnp.float32)
    else:
        C0, n0, m0 = carry

    def step(cr, inp):
        C, n, m = cr
        qj, kj, vj, Fj, ij, logDj = inp
        # qj (B,H,Q,D), Fj (B,H,Q), logDj (B,H,Q,Q)
        m_row = jnp.maximum(jnp.max(logDj, -1), Fj + m[..., None])  # (B,H,Q)
        m_row = jnp.maximum(m_row, NEG)
        Dm = jnp.exp(logDj - m_row[..., None])
        qk = jnp.einsum("bhld,bhsd->bhls", qj.astype(jnp.float32),
                        kj.astype(jnp.float32))
        Sm = qk * Dm
        inter_w = jnp.exp(Fj + m[..., None] - m_row)            # (B,H,Q)
        h_num = jnp.einsum("bhls,bhsd->bhld", Sm, vj.astype(jnp.float32)) \
            + inter_w[..., None] * jnp.einsum(
                "bhld,bhde->bhle", qj.astype(jnp.float32), C)
        qn = jnp.sum(Sm, -1) + inter_w * jnp.einsum(
            "bhld,bhd->bhl", qj.astype(jnp.float32), n)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_row))
        h = h_num / denom[..., None]
        # carry update to end of chunk
        FQ = Fj[..., -1:]                                       # (B,H,1)
        m_new = jnp.maximum(m + FQ[..., 0],
                            jnp.max(ij + FQ - Fj, axis=-1))
        m_new = jnp.maximum(m_new, NEG)
        w_old = jnp.exp(m + FQ[..., 0] - m_new)                 # (B,H)
        w_s = jnp.exp(ij + FQ - Fj - m_new[..., None])          # (B,H,Q)
        C = w_old[..., None, None] * C + jnp.einsum(
            "bhs,bhsd,bhse->bhde", w_s, kj.astype(jnp.float32),
            vj.astype(jnp.float32))
        n = w_old[..., None] * n + jnp.einsum(
            "bhs,bhsd->bhd", w_s, kj.astype(jnp.float32))
        return (C, n, m_new), h

    xs = (qc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
          vc.transpose(2, 0, 1, 3, 4), F.transpose(2, 0, 1, 3),
          ic.transpose(2, 0, 1, 3), logD.transpose(2, 0, 1, 3, 4))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    # hs: (nc, B, H, Q, D) -> (B, nc, Q, H, D) -> (B, S, H, D)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, D)
    return h.astype(q.dtype), (C, n, m)


def mlstm_block(p, x, cfg: ArchConfig, state: Optional[dict] = None):
    """x: (B,S,d). Returns (y, state')."""
    B, S, d = x.shape
    H = cfg.num_heads
    d_inner = 2 * d
    D = d_inner // H

    xu = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(x.dtype))
    xu = shard(xu, "batch", None, "ssm_inner")
    st = state or {}
    c, st_conv = _depthwise_conv(xu, p["conv_w"].astype(x.dtype),
                                 p["conv_b"].astype(x.dtype), st.get("conv"))
    c = jax.nn.silu(c)
    q = jnp.einsum("bse,ef->bsf", c, p["w_q"].astype(x.dtype)).reshape(B, S, H, D)
    k = jnp.einsum("bse,ef->bsf", c, p["w_k"].astype(x.dtype)).reshape(B, S, H, D)
    v = jnp.einsum("bse,ef->bsf", xu, p["w_v"].astype(x.dtype)).reshape(B, S, H, D)
    i_raw = jnp.einsum("bse,eh->bsh", xu, p["w_i"].astype(x.dtype)) \
        + p["b_i"].astype(x.dtype)
    f_raw = jnp.einsum("bse,eh->bsh", xu, p["w_f"].astype(x.dtype)) \
        + p["b_f"].astype(x.dtype)

    carry = None
    if "C" in st:
        carry = (st["C"], st["n"], st["m"])
    h, (C, n, m) = mlstm_chunked(q, k, v, i_raw, f_raw, chunk=128, carry=carry)

    # per-head group norm
    hf = h.astype(jnp.float32)
    mu = jnp.mean(hf, axis=-1, keepdims=True)
    var = jnp.var(hf, axis=-1, keepdims=True)
    hf = (hf - mu) * jax.lax.rsqrt(var + 1e-5)
    hf = hf.reshape(B, S, d_inner) * p["gn_scale"].astype(jnp.float32)
    h = hf.astype(x.dtype) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", h, p["w_down"].astype(x.dtype))
    y = shard(y, "batch", "act_seq", "embed")
    return y, {"conv": st_conv, "C": C, "n": n, "m": m}


def init_mlstm(b, name: str, cfg: ArchConfig, stack: int = 0):
    d = cfg.d_model
    d_inner = 2 * d
    H = cfg.num_heads
    with b.scope(name):
        b.add("w_up", (d, d_inner), ("embed", "ssm_inner"), stack=stack)
        b.add("w_z", (d, d_inner), ("embed", "ssm_inner"), stack=stack)
        b.add("conv_w", (4, d_inner), ("conv_width", "ssm_inner"),
              init="normal", scale=0.2, stack=stack)
        b.add("conv_b", (d_inner,), ("ssm_inner",), init="zeros", stack=stack)
        b.add("w_q", (d_inner, d_inner), ("ssm_inner", None), stack=stack)
        b.add("w_k", (d_inner, d_inner), ("ssm_inner", None), stack=stack)
        b.add("w_v", (d_inner, d_inner), ("ssm_inner", None), stack=stack)
        b.add("w_i", (d_inner, H), ("ssm_inner", "ssm_heads"), stack=stack)
        b.add("b_i", (H,), ("ssm_heads",), init="zeros", stack=stack)
        b.add("w_f", (d_inner, H), ("ssm_inner", "ssm_heads"), stack=stack)
        b.add("b_f", (H,), ("ssm_heads",), init="const", scale=3.0, stack=stack)
        b.add("gn_scale", (d_inner,), ("ssm_inner",), init="ones", stack=stack)
        b.add("w_down", (d_inner, d), ("ssm_inner", "embed"), stack=stack)


def make_mlstm_state(cfg: ArchConfig, batch: int, layers: int,
                     dtype=jnp.bfloat16):
    d_inner = 2 * cfg.d_model
    H = cfg.num_heads
    D = d_inner // H
    return {
        "conv": jnp.zeros((layers, batch, 3, d_inner), dtype),
        "C": jnp.zeros((layers, batch, H, D, D), jnp.float32),
        "n": jnp.zeros((layers, batch, H, D), jnp.float32),
        "m": jnp.full((layers, batch, H), NEG, jnp.float32),
    }


# ------------------------------------------------------------- sLSTM -------

def slstm_scan(x4, state, H: int, D: int, R):
    """x4: (B,S,H,4D) pre-activations for (i,f,z,o). R: (H,D,4D) recurrent.
    state: (h,c,n,m) each (B,H,D) except m (B,H,D).
    Returns (h_seq (B,S,H,D), state')."""
    def step(cr, xt):
        h, c, n, m = cr                                        # (B,H,D)
        rec = jnp.einsum("bhd,hde->bhe", h, R.astype(jnp.float32))
        pre = xt.astype(jnp.float32) + rec                     # (B,H,4D)
        ig, fg, zg, og = jnp.split(pre, 4, axis=-1)
        lf = _logsigmoid(fg)
        m_new = jnp.maximum(lf + m, ig)
        i_p = jnp.exp(ig - m_new)
        f_p = jnp.exp(lf + m - m_new)
        c = f_p * c + i_p * jnp.tanh(zg)
        n = f_p * n + i_p
        h = jax.nn.sigmoid(og) * c / jnp.maximum(n, 1e-6)
        return (h, c, n, m_new), h

    xs = x4.transpose(1, 0, 2, 3)                              # (S,B,H,4D)
    state2, hs = jax.lax.scan(step, state, xs)
    return hs.transpose(1, 0, 2, 3), state2                    # (B,S,H,D)


def slstm_block(p, x, cfg: ArchConfig, state: Optional[dict] = None):
    """sLSTM block with post-up-projection (4/3) gated FF."""
    B, S, d = x.shape
    H = cfg.num_heads
    D = d // H
    x4 = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype)) \
        + p["b_in"].astype(x.dtype)
    x4 = x4.reshape(B, S, H, 4 * D)
    if state is None:
        z = jnp.zeros((B, H, D), jnp.float32)
        st = (z, z, z, jnp.full((B, H, D), NEG, jnp.float32))
    else:
        st = (state["h"], state["c"], state["n"], state["m"])
    h, (hh, cc, nn, mm) = slstm_scan(x4, st, H, D, p["R"])
    # group norm per head
    hf = h.astype(jnp.float32)
    mu = jnp.mean(hf, -1, keepdims=True)
    var = jnp.var(hf, -1, keepdims=True)
    hf = (hf - mu) * jax.lax.rsqrt(var + 1e-5)
    hf = hf.reshape(B, S, d) * p["gn_scale"].astype(jnp.float32)
    y = hf.astype(x.dtype)
    # gated FF (4/3 factor)
    f_up = jnp.einsum("bsd,df->bsf", y, p["ff_up"].astype(x.dtype))
    f_gate = jnp.einsum("bsd,df->bsf", y, p["ff_gate"].astype(x.dtype))
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(f_gate) * f_up,
                   p["ff_down"].astype(x.dtype))
    y = shard(y, "batch", "act_seq", "embed")
    return y, {"h": hh, "c": cc, "n": nn, "m": mm}


def init_slstm(b, name: str, cfg: ArchConfig, stack: int = 0):
    d = cfg.d_model
    H = cfg.num_heads
    D = d // H
    f = int(d * 4 / 3) // 8 * 8
    with b.scope(name):
        b.add("w_in", (d, 4 * d), ("embed", "ssm_inner"), stack=stack)
        b.add("b_in", (4 * d,), ("ssm_inner",), init="zeros", stack=stack)
        b.add("R", (H, D, 4 * D), ("ssm_heads", None, None), stack=stack)
        b.add("gn_scale", (d,), ("embed",), init="ones", stack=stack)
        b.add("ff_up", (d, f), ("embed", "mlp"), stack=stack)
        b.add("ff_gate", (d, f), ("embed", "mlp"), stack=stack)
        b.add("ff_down", (f, d), ("mlp", "embed"), stack=stack)


def make_slstm_state(cfg: ArchConfig, batch: int, layers: int):
    H = cfg.num_heads
    D = cfg.d_model // H
    z = jnp.zeros((layers, batch, H, D), jnp.float32)
    return {"h": z, "c": z, "n": z,
            "m": jnp.full((layers, batch, H, D), NEG, jnp.float32)}
