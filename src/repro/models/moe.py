"""Mixture-of-Experts layer: top-k routing, capacity-bounded gather/scatter.

Dispatch is grouped **per batch row** (GShard-style groups = batch dim):
the position-in-expert cumsum runs over the row-local S·k assignment list, so
under batch sharding it never crosses devices; the scatter into the
expert-sharded buffer is the only cross-device step and lowers to the
standard EP all-to-all over the "model"/"experts" mesh axis. No O(T·E·C)
one-hot dispatch tensor is ever materialized.

Dropping semantics: assignments beyond per-row capacity C = ceil(S·k·cf/E)
are dropped (token keeps its residual), exactly as in Switch/GShard.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import shard


def _capacity(tokens_per_group: int, num_experts: int, top_k: int,
              factor: float) -> int:
    cap = int(math.ceil(tokens_per_group * top_k * factor / num_experts))
    return max(8, ((cap + 7) // 8) * 8)       # pad to 8 for TPU lanes


def route(p, x: jax.Array, cfg: ArchConfig):
    """x: (B, S, d). Returns (expert_idx (B,S,k), gate (B,S,k), aux_loss)."""
    moe = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["w_router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, moe.top_k)           # (B,S,k)
    gate = gate / jnp.maximum(jnp.sum(gate, -1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))                     # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], moe.num_experts,
                                 dtype=jnp.float32), axis=(0, 1))
    aux = moe.num_experts * jnp.sum(me * ce)
    return idx, gate, aux


def _dispatch_row(x_row, dest, EC):
    """x_row: (S*k source tokens gathered, d); dest: (S*k,) in [0, EC]."""
    buf = jnp.zeros((EC + 1, x_row.shape[-1]), x_row.dtype)
    return buf.at[dest].set(x_row)[:EC]


def moe_mlp(p, x: jax.Array, cfg: ArchConfig,
            dispatch: str = "auto") -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    dispatch="einsum": GShard-style one-hot dispatch/combine matmuls. Under
    expert sharding the dispatch contraction is rank-local (zero comm) and
    the combine is one partial-sum all-reduce of (B,S,d) per layer — 7.2x
    less collective volume than the scatter lowering on granite
    (EXPERIMENTS §Perf). Its (S,E,C) combine tensor is O(S·S·k·cf) per row,
    so "auto" falls back to the scatter/gather path for long unsharded-
    expert sequences (mixtral prefill_32k: einsum measured 5x WORSE there).
    """
    if dispatch == "auto":
        from repro.parallel.sharding import current_policy
        pol = current_policy()
        ep = (pol is not None and pol.mesh is not None
              and pol.rules.get("experts") is not None)
        dispatch = "einsum" if (ep or x.shape[1] <= 8192) else "scatter"
    if dispatch == "einsum":
        return moe_mlp_einsum(p, x, cfg)
    return moe_mlp_scatter(p, x, cfg)


def moe_mlp_einsum(p, x: jax.Array, cfg: ArchConfig):
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.num_experts, moe.top_k
    C = _capacity(S, E, k, moe.capacity_factor)

    idx, gate, aux = route(p, x, cfg)                     # (B,S,k)
    with jax.named_scope("moe_dispatch"):
        onehot_e = jax.nn.one_hot(idx, E, dtype=jnp.int32)  # (B,S,k,E)
        # row-local position of assignment within its expert (flat S*k)
        flat = onehot_e.reshape(B, S * k, E)
        pos = (jnp.cumsum(flat, axis=1) - flat).reshape(B, S, k, E)
        pos = jnp.sum(pos * onehot_e, axis=-1)            # (B,S,k)
        keep = (pos < C).astype(x.dtype) * gate.astype(x.dtype)

        # combine[b,s,e,c] = sum_k gate_k * 1[idx=e] * 1[pos=c]
        combine = jnp.zeros((B, S, E, C), x.dtype)
        for kk in range(k):                               # k is small (2/8)
            oh_c = jax.nn.one_hot(pos[:, :, kk], C, dtype=x.dtype)
            combine = combine + (keep[:, :, kk, None, None]
                                 * onehot_e[:, :, kk, :, None].astype(x.dtype)
                                 * oh_c[:, :, None, :])
        combine = shard(combine, "batch", None, "experts",
                        "expert_capacity")
        disp = (combine > 0).astype(x.dtype)

    buf = jnp.einsum("bsec,bsd->becd", disp, x,
                     preferred_element_type=x.dtype)
    buf = shard(buf, "batch", "experts", "expert_capacity", "embed")

    wg, wu, wd = (p["w_gate"].astype(x.dtype), p["w_up"].astype(x.dtype),
                  p["w_down"].astype(x.dtype))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) \
        * jnp.einsum("becd,edf->becf", buf, wu)
    h = shard(h, "batch", "experts", "expert_capacity", "mlp")
    y_e = jnp.einsum("becf,efd->becd", h, wd)
    y_e = shard(y_e, "batch", "experts", "expert_capacity", "embed")

    y = jnp.einsum("bsec,becd->bsd", combine, y_e,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return shard(y, "batch", "act_seq", "embed"), aux.astype(jnp.float32)


def moe_mlp_scatter(p, x: jax.Array, cfg: ArchConfig):
    moe = cfg.moe
    B, S, d = x.shape
    E, k = moe.num_experts, moe.top_k
    C = _capacity(S, E, k, moe.capacity_factor)

    idx, gate, aux = route(p, x, cfg)                     # (B,S,k)

    flat_e = idx.reshape(B, S * k)
    flat_t = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(S * k)
    flat_g = gate.reshape(B, S * k)
    # row-local position of each assignment within its expert
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)   # (B, S*k, E)
    pos = jnp.sum((jnp.cumsum(onehot, axis=1) - onehot) * onehot, axis=-1)
    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)       # drop -> scratch row

    x_src = x[:, flat_t, :]                               # (B, S*k, d)
    buf = jax.vmap(_dispatch_row, in_axes=(0, 0, None))(x_src, dest, E * C)
    buf = buf.reshape(B, E, C, d)
    buf = shard(buf, "batch", "experts", "expert_capacity", "embed")

    # expert computation (SwiGLU), batched over (B, E)
    wg, wu, wd = (p["w_gate"].astype(x.dtype), p["w_up"].astype(x.dtype),
                  p["w_down"].astype(x.dtype))
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg)) \
        * jnp.einsum("becd,edf->becf", buf, wu)
    h = shard(h, "batch", "experts", "expert_capacity", "mlp")
    y_e = jnp.einsum("becf,efd->becd", h, wd)
    y_e = shard(y_e, "batch", "experts", "expert_capacity", "embed")

    # gather back, weight by gates, combine top-k
    y_flat = y_e.reshape(B, E * C, d)
    safe = jnp.minimum(dest, E * C - 1)
    y_slots = jnp.take_along_axis(y_flat, safe[..., None], axis=1)
    y_slots = jnp.where(keep[..., None], y_slots, 0.0)    # (B, S*k, d)
    y = jnp.sum(
        (y_slots * flat_g[..., None].astype(x.dtype)).reshape(B, S, k, d),
        axis=2)
    return shard(y, "batch", "act_seq", "embed"), aux.astype(jnp.float32)


def init_moe(b, name: str, cfg: ArchConfig, stack: int = 0):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    with b.scope(name):
        b.add("w_router", (d, E), ("embed", "experts"), stack=stack)
        b.add("w_gate", (E, d, f), ("experts", "embed", "mlp"), stack=stack)
        b.add("w_up", (E, d, f), ("experts", "embed", "mlp"), stack=stack)
        b.add("w_down", (E, f, d), ("experts", "mlp", "embed"), stack=stack)
