"""Shared layers: norms, MLPs, RoPE, embeddings. Pure functions over pytrees."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.parallel.sharding import shard


def norm(p, x: jax.Array, kind: str) -> jax.Array:
    """RMSNorm / LayerNorm with fp32 statistics."""
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + 1e-6) * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


def init_norm(b, name: str, d: int, kind: str, stack: int = 0):
    with b.scope(name):
        b.add("scale", (d,), ("embed",), init="ones", stack=stack)
        if kind == "layernorm":
            b.add("bias", (d,), ("embed",), init="zeros", stack=stack)


def mlp(p, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """SwiGLU or (biased) GELU MLP, TP-sharded on d_ff."""
    if cfg.mlp == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.gelu(h + p["b_up"].astype(x.dtype))
    h = shard(h, "batch", None, "mlp")
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    if "b_down" in p:
        y = y + p["b_down"].astype(x.dtype)
    return shard(y, "batch", "act_seq", "embed")


def init_mlp(b, name: str, cfg: ArchConfig, stack: int = 0):
    d, f = cfg.d_model, cfg.d_ff
    with b.scope(name):
        if cfg.mlp == "swiglu":
            b.add("w_gate", (d, f), ("embed", "mlp"), stack=stack)
            b.add("w_up", (d, f), ("embed", "mlp"), stack=stack)
        else:
            b.add("w_up", (d, f), ("embed", "mlp"), stack=stack)
            b.add("b_up", (f,), ("mlp",), init="zeros", stack=stack)
            b.add("b_down", (d,), ("embed",), init="zeros", stack=stack)
        b.add("w_down", (f, d), ("mlp", "embed"), stack=stack)


# ---------------------------------------------------------------- RoPE -----

def rope_freqs(head_dim: int, mode: str, base: float = 10000.0) -> jax.Array:
    """Inverse frequencies. mode="half" (GLM 2d-RoPE) rotates only the first
    half of the head dims."""
    rot = head_dim if mode == "full" else head_dim // 2
    return 1.0 / (base ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def rope_apply(x: jax.Array, positions: jax.Array, mode: str,
               base: float = 10000.0) -> jax.Array:
    """x: (..., S, *head_dims, head_dim); positions: (S,) or (B, S)."""
    if mode == "none":
        return x
    hd = x.shape[-1]
    inv = rope_freqs(hd, mode, base)
    ang = positions[..., None].astype(jnp.float32) * inv          # (..., S, rot/2)
    # insert singleton axes for the head dims between S and head_dim
    n_mid = x.ndim - ang.ndim - 1
    ang = ang.reshape(ang.shape[:-1] + (1,) * n_mid + ang.shape[-1:])
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    rot = hd if mode == "full" else hd // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr.astype(x.dtype), xp], axis=-1) if mode == "half" \
        else yr.astype(x.dtype)


# ---------------------------------------------------------- Embeddings -----

def embed(p, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    e = jnp.take(p["embedding"], tokens, axis=0).astype(cfg.adtype)
    return shard(e, "batch", "act_seq", "embed")


def unembed(p_root, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        w = p_root["embed"]["embedding"].astype(x.dtype).T
    else:
        w = p_root["unembed"]["w"].astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return shard(logits, "batch", "logit_seq", "vocab")


def init_embeddings(b, cfg: ArchConfig):
    with b.scope("embed"):
        b.add("embedding", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
              scale=0.02)
    if not cfg.tie_embeddings:
        with b.scope("unembed"):
            b.add("w", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    if cfg.rope == "none" and cfg.max_position_embeddings:
        with b.scope("pos_embed"):
            b.add("embedding", (cfg.max_position_embeddings, cfg.d_model),
                  (None, "embed"), scale=0.02)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
