"""Per-family model stacks: dense/MoE/VLM decoder, whisper enc-dec,
xLSTM stack, Zamba2 hybrid (Mamba2 + shared attention block).

All deep stacks scan over layers with stacked parameters so the HLO stays
O(1) in depth (essential for 126-layer AOT compiles); training wraps the
scan body in ``jax.checkpoint`` (full per-layer remat).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import xlstm as xl
from repro.models.attention import attention, init_attention
from repro.models.layers import init_mlp, init_norm, mlp, norm
from repro.models.moe import init_moe, moe_mlp
from repro.models.ssm import init_mamba, mamba_block
from repro.parallel.sharding import shard


def _maybe_ckpt(fn, cfg: ArchConfig, kind: str):
    return jax.checkpoint(fn) if (cfg.remat and kind == "train") else fn


# ----------------------------------------------------- transformer block ---

def transformer_block(p, x, cfg: ArchConfig, *, causal=True, cache=None,
                      pos=None, kind="train", decode_ring=False):
    h = norm(p["ln1"], x, cfg.norm)
    a, new_cache = attention(p["attn"], h, cfg, causal=causal, cache=cache,
                             pos=pos, decode_ring=decode_ring)
    x = x + a
    h = norm(p["ln2"], x, cfg.norm)
    if cfg.moe is not None:
        y, aux = moe_mlp(p["moe"], h, cfg)
    else:
        y, aux = mlp(p["mlp"], h, cfg), jnp.float32(0)
    return x + y, new_cache, aux


def init_transformer_block(b, cfg: ArchConfig, stack: int = 0):
    init_norm(b, "ln1", cfg.d_model, cfg.norm, stack=stack)
    init_attention(b, "attn", cfg, stack=stack)
    init_norm(b, "ln2", cfg.d_model, cfg.norm, stack=stack)
    if cfg.moe is not None:
        init_moe(b, "moe", cfg, stack=stack)
    else:
        init_mlp(b, "mlp", cfg, stack=stack)


def dense_stack(p_stack, x, cfg: ArchConfig, cache=None, pos=None,
                kind="train", causal=True, decode_ring=False):
    """Scan over stacked transformer blocks. cache: pytree with leading L dim."""
    def body(carry, xs):
        xx, aux = carry
        if cache is not None:
            p_l, c_l = xs
        else:
            p_l, c_l = xs, None
        xx, new_c, a = transformer_block(
            p_l, xx, cfg, causal=causal, cache=c_l, pos=pos, kind=kind,
            decode_ring=decode_ring)
        return (xx, aux + a), new_c

    body = _maybe_ckpt(body, cfg, kind)
    xs = (p_stack, cache) if cache is not None else p_stack
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, new_cache, aux


# ------------------------------------------------------------- whisper -----

def whisper_encoder(p, frames, cfg: ArchConfig, kind="train"):
    """frames: (B, T, d) precomputed conv-frontend embeddings (STUB)."""
    T = frames.shape[1]
    x = frames.astype(cfg.adtype) \
        + p["enc_pos"]["embedding"][:T].astype(cfg.adtype)
    x = shard(x, "batch", "act_seq", "embed")

    def body(carry, p_l):
        xx, _ = carry
        h = norm(p_l["ln1"], xx, cfg.norm)
        a, _ = attention(p_l["attn"], h, cfg, causal=False)
        xx = xx + a
        h = norm(p_l["ln2"], xx, cfg.norm)
        xx = xx + mlp(p_l["mlp"], h, cfg)
        return (xx, jnp.float32(0)), None

    body = _maybe_ckpt(body, cfg, kind)
    (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0)), p["enc_layers"])
    return norm(p["enc_ln_f"], x, cfg.norm)


def whisper_cross_kv(p, enc_out, cfg: ArchConfig):
    """Per-decoder-layer cross K/V from encoder output: (L,B,T,K,H) each."""
    def body(_, p_l):
        k = jnp.einsum("btd,dkh->btkh", enc_out,
                       p_l["xattn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("btd,dkh->btkh", enc_out,
                       p_l["xattn"]["wv"].astype(enc_out.dtype))
        return None, (k, v)
    _, (xk, xv) = jax.lax.scan(body, None, p["dec_layers"])
    return xk, xv


def whisper_decoder_block(p_l, x, cfg: ArchConfig, cross_kv, cache=None,
                          pos=None, kind="train"):
    h = norm(p_l["ln1"], x, cfg.norm)
    a, new_cache = attention(p_l["attn"], h, cfg, causal=True, cache=cache,
                             pos=pos, rope_mode="none")
    x = x + a
    h = norm(p_l["lnx"], x, cfg.norm)
    a, _ = attention(p_l["xattn"], h, cfg, causal=False, cross_kv=cross_kv,
                     rope_mode="none")
    x = x + a
    h = norm(p_l["ln2"], x, cfg.norm)
    return x + mlp(p_l["mlp"], h, cfg), new_cache


def init_whisper(b, cfg: ArchConfig):
    with b.scope("enc_pos"):
        b.add("embedding", (cfg.encoder_seq, cfg.d_model), (None, "embed"),
              scale=0.02)
    with b.scope("enc_layers"):
        init_norm(b, "ln1", cfg.d_model, cfg.norm, stack=cfg.encoder_layers)
        init_attention(b, "attn", cfg, stack=cfg.encoder_layers, bias=True)
        init_norm(b, "ln2", cfg.d_model, cfg.norm, stack=cfg.encoder_layers)
        init_mlp(b, "mlp", cfg, stack=cfg.encoder_layers)
    init_norm(b, "enc_ln_f", cfg.d_model, cfg.norm)
    with b.scope("dec_layers"):
        init_norm(b, "ln1", cfg.d_model, cfg.norm, stack=cfg.num_layers)
        init_attention(b, "attn", cfg, stack=cfg.num_layers, bias=True)
        init_norm(b, "lnx", cfg.d_model, cfg.norm, stack=cfg.num_layers)
        init_attention(b, "xattn", cfg, stack=cfg.num_layers, bias=True)
        init_norm(b, "ln2", cfg.d_model, cfg.norm, stack=cfg.num_layers)
        init_mlp(b, "mlp", cfg, stack=cfg.num_layers)


def whisper_decoder(p, x, cfg: ArchConfig, cross_kv, cache=None, pos=None,
                    kind="train"):
    """cross_kv: (xk, xv) stacked (L,B,T,K,H)."""
    xk, xv = cross_kv

    def body(carry, xs):
        xx, _ = carry
        if cache is not None:
            p_l, xk_l, xv_l, c_l = xs
        else:
            p_l, xk_l, xv_l = xs
            c_l = None
        xx, new_c = whisper_decoder_block(p_l, xx, cfg, (xk_l, xv_l),
                                          cache=c_l, pos=pos, kind=kind)
        return (xx, jnp.float32(0)), new_c

    body = _maybe_ckpt(body, cfg, kind)
    xs = (p["dec_layers"], xk, xv) + ((cache,) if cache is not None else ())
    (x, _), new_cache = jax.lax.scan(body, (x, jnp.float32(0)), xs)
    return x, new_cache


# --------------------------------------------------------------- xLSTM -----

def xlstm_stack(p, x, cfg: ArchConfig, state=None, kind="train"):
    """Unrolled (L=12 is small). state: dict block_i -> block state."""
    new_state = {}
    for i in range(cfg.num_layers):
        key = f"block_{i}"
        p_b = p[key]
        st = None if state is None else state[key]
        h = norm(p_b["ln"], x, "layernorm")
        if i in cfg.slstm_at:
            y, st2 = xl.slstm_block(p_b["cell"], h, cfg, st)
        else:
            y, st2 = xl.mlstm_block(p_b["cell"], h, cfg, st)
        x = x + y
        new_state[key] = st2
    return x, new_state


def init_xlstm(b, cfg: ArchConfig):
    for i in range(cfg.num_layers):
        with b.scope(f"block_{i}"):
            init_norm(b, "ln", cfg.d_model, "layernorm")
            if i in cfg.slstm_at:
                xl.init_slstm(b, "cell", cfg)
            else:
                xl.init_mlstm(b, "cell", cfg)


# ------------------------------------------------------------- Zamba2 ------

def zamba_layout(cfg: ArchConfig) -> Tuple[int, int, int]:
    """(n_units, mamba_per_unit, tail) — each unit = (period-1) mamba + 1
    shared attention application; tail = trailing mamba blocks."""
    period = cfg.shared_attn_period
    n_units = cfg.num_layers // period
    tail = cfg.num_layers % period
    return n_units, period - 1, tail


def zamba_stack(p, x, cfg: ArchConfig, cache=None, pos=None, kind="train",
                decode_ring=False):
    """Macro scan over units; shared transformer block weights are reused by
    every application (Zamba's parameter-sharing trick)."""
    n_units, m_per, tail = zamba_layout(cfg)
    shared = p["shared"]

    def mamba_body(carry, xs):
        xx, _ = carry
        if cache is not None:
            p_l, st_l = xs
        else:
            p_l, st_l = xs, None
        h = norm(p_l["ln"], xx, cfg.norm)
        y, st2 = mamba_block(p_l["cell"], h, cfg, st_l)
        return (xx + y, jnp.float32(0)), st2

    mamba_body = _maybe_ckpt(mamba_body, cfg, kind)

    def unit_body(carry, xs):
        xx, aux = carry
        if cache is not None:
            p_u, st_u, attn_c = xs
        else:
            p_u, st_u, attn_c = xs, None, None
        inner_xs = (p_u, st_u) if cache is not None else p_u
        (xx, _), st2 = jax.lax.scan(mamba_body, (xx, jnp.float32(0)), inner_xs)
        xx, attn_c2, a = transformer_block(
            shared, xx, cfg, causal=True, cache=attn_c, pos=pos, kind=kind,
            decode_ring=decode_ring)
        return (xx, aux + a), (st2, attn_c2)

    # stacked (n_units*m_per, ...) -> nested (n_units, m_per, ...) for the
    # two-level scan
    p_units = jax.tree.map(
        lambda a: a.reshape((n_units, m_per) + a.shape[1:]), p["mamba_units"])
    if cache is not None:
        xs = (p_units, cache["mamba_units"], cache["attn"])
    else:
        xs = p_units
    (x, aux), ys = jax.lax.scan(unit_body, (x, jnp.float32(0)), xs)
    new_cache = None
    if cache is not None:
        new_cache = {"mamba_units": ys[0], "attn": ys[1]}

    if tail:
        inner_xs = (p["mamba_tail"], cache["mamba_tail"]) \
            if cache is not None else p["mamba_tail"]
        (x, _), st_tail = jax.lax.scan(mamba_body, (x, jnp.float32(0)), inner_xs)
        if cache is not None:
            new_cache["mamba_tail"] = st_tail
    return x, new_cache, aux


def init_zamba(b, cfg: ArchConfig):
    n_units, m_per, tail = zamba_layout(cfg)
    with b.scope("mamba_units"):
        # stacked (n_units * m_per, ...); reshaped to (n_units, m_per, ...)
        init_norm(b, "ln", cfg.d_model, cfg.norm, stack=n_units * m_per)
        init_mamba(b, "cell", cfg, stack=n_units * m_per)
    if tail:
        with b.scope("mamba_tail"):
            init_norm(b, "ln", cfg.d_model, cfg.norm, stack=tail)
            init_mamba(b, "cell", cfg, stack=tail)
    with b.scope("shared"):
        init_transformer_block(b, cfg)
