"""Parameter construction with logical-axis tracking.

``ParamBuilder`` creates (nested-dict) parameter pytrees while recording, at
the same code site, the logical axes of every leaf — one code path for both
values and shardings, so they cannot drift apart. ``AxisTree`` mirrors the
param pytree with tuples of logical axis names.
"""
from __future__ import annotations

import contextlib
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Axes = Tuple[Optional[str], ...]


class ParamBuilder:
    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: Dict = {}
        self.axes: Dict = {}
        self._path = []

    @contextlib.contextmanager
    def scope(self, name: str):
        self._path.append(name)
        try:
            yield self
        finally:
            self._path.pop()

    def _enter(self, tree: Dict) -> Dict:
        node = tree
        for p in self._path:
            node = node.setdefault(p, {})
        return node

    def _next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def add(self, name: str, shape: Tuple[int, ...], axes: Axes,
            init: str = "normal", scale: Optional[float] = None,
            stack: int = 0) -> jax.Array:
        """Create one parameter. ``stack`` prepends a scan-stacked layer dim
        (axes gets "layers" prepended)."""
        if stack:
            shape = (stack,) + tuple(shape)
            axes = ("layers",) + tuple(axes)
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "zeros":
            val = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            val = jnp.ones(shape, self.dtype)
        elif init == "normal":
            if scale is None:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / math.sqrt(max(1, fan_in))
            val = (jax.random.normal(self._next_key(), shape, jnp.float32)
                   * scale).astype(self.dtype)
        elif init == "const":
            val = jnp.full(shape, scale, self.dtype)
        else:
            raise ValueError(init)
        self._enter(self.params)[name] = val
        self._enter(self.axes)[name] = tuple(axes)
        return val


def tree_axes_flatten(axes_tree) -> Dict[str, Axes]:
    flat = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, path + (k,))
        else:
            flat["/".join(path)] = node
    rec(axes_tree, ())
    return flat
