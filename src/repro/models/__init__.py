from repro.models.model import (build_model, Model, count_params,
                                abstract_params, param_partition_specs)

__all__ = ["build_model", "Model", "count_params", "abstract_params",
           "param_partition_specs"]
