"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1)-state
recurrence for decode. Follows the minimal-SSD formulation of the Mamba2
paper (scalar-identity A per head, groups=1), TPU-adapted: the chunked
intra/inter decomposition maps chunk-local work onto MXU matmuls and the
inter-chunk recurrence onto a short ``lax.scan`` (S/chunk steps).

Projections are kept as separate weights (z / x / B / C / dt) rather than one
packed in-proj so each output dim carries a clean sharding (d_inner and heads
shard over "model"; the tiny N=64 state dims stay replicated).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, SSMConfig
from repro.parallel.sharding import shard


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) with sum_{j+1..i}, -inf above diag."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def _pick_chunk(S: int, chunk: int) -> int:
    c = min(chunk, S)
    while S % c:
        c -= 1
    return c


@jax.named_scope("ssd_chunk")
def ssd_chunked(x, a, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD.

    x: (B, S, H, P) inputs (already multiplied by dt)
    a: (B, S, H) log decay (dt * A, negative)
    Bm, Cm: (B, S, N) input/output projections (groups=1, broadcast over H)
    Returns (y (B,S,H,P), final_state (B,H,P,N) fp32).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    Q = _pick_chunk(s, chunk)
    nc = s // Q
    xc = x.reshape(b, nc, Q, h, p)
    ac = a.reshape(b, nc, Q, h).transpose(0, 3, 1, 2)          # (b,h,nc,Q)
    Bc = Bm.reshape(b, nc, Q, n)
    Cc = Cm.reshape(b, nc, Q, n)

    a_cs = jnp.cumsum(ac, axis=-1)                             # (b,h,nc,Q)
    L = jnp.exp(_segsum(ac))                                   # (b,h,nc,Q,Q)

    # 1. intra-chunk (diagonal blocks): scores[l,s] = (C_l . B_s) * L[l,s]
    cb = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)                 # (b,nc,Q,Q)
    y_diag = jnp.einsum("bcls,bhcls,bcshp->bclhp", cb, L, xc)

    # 2. chunk summary states with decay from position to chunk end
    decay_to_end = jnp.exp(a_cs[..., -1:] - a_cs)              # (b,h,nc,Q)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", Bc, decay_to_end, xc)

    # 3. inter-chunk recurrence (sequential over nc chunks)
    chunk_decay = jnp.exp(a_cs[..., -1])                       # (b,h,nc)

    def step(carry, inp):
        st_c, dec_c = inp                                      # (b,h,p,n),(b,h)
        new = carry * dec_c[..., None, None] + st_c
        return new, carry                                      # emit PREV state

    init = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),  # (nc,b,h,p,n)
         chunk_decay.transpose(2, 0, 1)))                      # (nc,b,h)
    prev_states = prev_states.transpose(1, 2, 0, 3, 4)         # (b,h,nc,p,n)

    # 4. off-diagonal: C_l . prev_state, decayed from chunk start
    state_decay = jnp.exp(a_cs)                                # (b,h,nc,Q)
    y_off = jnp.einsum("bcln,bhcpn,bhcl->bclhp",
                       Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode(x, a, Bm, Cm, state):
    """Single-token SSD recurrence.

    x: (B, 1, H, P) (already * dt); a: (B, 1, H) log decay;
    Bm, Cm: (B, 1, N); state: (B, H, P, N) fp32.
    """
    dA = jnp.exp(a[:, 0].astype(jnp.float32))                  # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", x[:, 0].astype(jnp.float32),
                     Bm[:, 0].astype(jnp.float32))
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm[:, 0].astype(jnp.float32))
    return y[:, None].astype(x.dtype), new_state


def _depthwise_conv(x, w, b, state=None):
    """Causal depthwise conv, width W. x: (B,S,D), w: (W,D), b: (D,).
    state: (B, W-1, D) trailing past inputs for decode/chunked prefill."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros_like(x[:, :0])
    return y.astype(x.dtype), new_state


def mamba_block(p, x, cfg: ArchConfig, state: Optional[dict] = None):
    """Mamba2 block. x: (B,S,d).
    state: None | dict(conv_x/conv_B/conv_C, ssm=(B,H,P,N) fp32).
    Returns (y, new_state) — state always returned (prefill populates it).
    """
    s_cfg = cfg.ssm or SSMConfig()
    B_, S, d = x.shape
    d_inner = s_cfg.expand * d
    P = s_cfg.head_dim
    H = s_cfg.num_heads or d_inner // P

    z = jnp.einsum("bsd,de->bse", x, p["w_z"].astype(x.dtype))
    z = shard(z, "batch", None, "ssm_inner")
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(x.dtype))
    Bi = jnp.einsum("bsd,dn->bsn", x, p["w_B"].astype(x.dtype))
    Ci = jnp.einsum("bsd,dn->bsn", x, p["w_C"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(x.dtype))

    st = state or {}
    xs, st_x = _depthwise_conv(xi, p["conv_x_w"].astype(x.dtype),
                               p["conv_x_b"].astype(x.dtype), st.get("conv_x"))
    Bm, st_B = _depthwise_conv(Bi, p["conv_B_w"].astype(x.dtype),
                               p["conv_B_b"].astype(x.dtype), st.get("conv_B"))
    Cm, st_C = _depthwise_conv(Ci, p["conv_C_w"].astype(x.dtype),
                               p["conv_C_b"].astype(x.dtype), st.get("conv_C"))
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    xs = shard(xs, "batch", None, "ssm_inner")

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))    # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (H,)
    a = dt * A                                                  # log decay
    xh = xs.reshape(B_, S, H, P)
    xbar = xh * dt[..., None].astype(x.dtype)

    if state is None or S > 1:
        y, ssm_state = ssd_chunked(xbar, a, Bm, Cm, s_cfg.chunk,
                                   init_state=st.get("ssm"))
    else:
        y, ssm_state = ssd_decode(xbar, a, Bm, Cm, st["ssm"])

    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B_, S, d_inner)
    # gated RMSNorm (Mamba2)
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    y = yf.astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(x.dtype))
    out = shard(out, "batch", "act_seq", "embed")
    new_state = {"conv_x": st_x, "conv_B": st_B, "conv_C": st_C,
                 "ssm": ssm_state}
    return out, new_state


def init_mamba(b, name: str, cfg: ArchConfig, stack: int = 0):
    s_cfg = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_inner = s_cfg.expand * d
    P = s_cfg.head_dim
    H = s_cfg.num_heads or d_inner // P
    N = s_cfg.state_dim
    W = s_cfg.conv_width
    with b.scope(name):
        b.add("w_z", (d, d_inner), ("embed", "ssm_inner"), stack=stack)
        b.add("w_x", (d, d_inner), ("embed", "ssm_inner"), stack=stack)
        b.add("w_B", (d, N), ("embed", "ssm_state"), stack=stack)
        b.add("w_C", (d, N), ("embed", "ssm_state"), stack=stack)
        b.add("w_dt", (d, H), ("embed", "ssm_heads"), stack=stack)
        b.add("conv_x_w", (W, d_inner), ("conv_width", "ssm_inner"),
              init="normal", scale=0.2, stack=stack)
        b.add("conv_x_b", (d_inner,), ("ssm_inner",), init="zeros", stack=stack)
        b.add("conv_B_w", (W, N), ("conv_width", "ssm_state"),
              init="normal", scale=0.2, stack=stack)
        b.add("conv_B_b", (N,), ("ssm_state",), init="zeros", stack=stack)
        b.add("conv_C_w", (W, N), ("conv_width", "ssm_state"),
              init="normal", scale=0.2, stack=stack)
        b.add("conv_C_b", (N,), ("ssm_state",), init="zeros", stack=stack)
        b.add("dt_bias", (H,), ("ssm_heads",), init="zeros", stack=stack)
        b.add("A_log", (H,), ("ssm_heads",), init="zeros", stack=stack)
        b.add("D", (H,), ("ssm_heads",), init="ones", stack=stack)
        b.add("norm_scale", (d_inner,), ("ssm_inner",), init="ones", stack=stack)
        b.add("w_out", (d_inner, d), ("ssm_inner", "embed"), stack=stack)


def make_mamba_state(cfg: ArchConfig, batch: int, layers: int,
                     dtype=jnp.bfloat16):
    s_cfg = cfg.ssm or SSMConfig()
    d_inner = s_cfg.expand * cfg.d_model
    P = s_cfg.head_dim
    H = s_cfg.num_heads or d_inner // P
    N = s_cfg.state_dim
    W1 = s_cfg.conv_width - 1
    return {
        "conv_x": jnp.zeros((layers, batch, W1, d_inner), dtype),
        "conv_B": jnp.zeros((layers, batch, W1, N), dtype),
        "conv_C": jnp.zeros((layers, batch, W1, N), dtype),
        "ssm": jnp.zeros((layers, batch, H, P, N), jnp.float32),
    }
