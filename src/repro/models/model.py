"""Model API: build_model(cfg) -> Model with init/forward/loss/prefill/decode,
abstract parameter/cache templates (for AOT dry-runs) and logical-axis trees
(for shardings). Everything is family-dispatched here; the rest of the
framework only sees this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models import xlstm as xl
from repro.models.layers import (cross_entropy, embed, init_embeddings, norm,
                                 init_norm, unembed)
from repro.models.params import ParamBuilder
from repro.models.ssm import SSMConfig
from repro.parallel.sharding import shard

Pytree = Any


# ------------------------------------------------------------ init ---------

def init_arch(b: ParamBuilder, cfg: ArchConfig):
    init_embeddings(b, cfg)
    if cfg.family in ("dense", "moe", "vlm"):
        with b.scope("layers"):
            tf.init_transformer_block(b, cfg, stack=cfg.num_layers)
    elif cfg.family == "audio":
        tf.init_whisper(b, cfg)
    elif cfg.family == "ssm":
        tf.init_xlstm(b, cfg)
    elif cfg.family == "hybrid":
        tf.init_zamba(b, cfg)
    else:
        raise ValueError(cfg.family)
    init_norm(b, "ln_f", cfg.d_model, cfg.norm)


# ----------------------------------------------------- cache templates -----

def _kv_shape(cfg, layers, batch, seq):
    return (layers, batch, seq, cfg.num_kv_heads, cfg.hd)

KV_AXES = ("layers", "cache_batch", "cache_seq", "kv_heads", "head_dim")


def cache_template(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Tuple[Pytree, Pytree]:
    """Returns (spec_tree of ShapeDtypeStruct, axes_tree of tuples)."""
    S = jax.ShapeDtypeStruct
    if cfg.family in ("dense", "moe", "vlm"):
        seq = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
        sh = _kv_shape(cfg, cfg.num_layers, batch, seq)
        spec = {"k": S(sh, dtype), "v": S(sh, dtype)}
        axes = {"k": KV_AXES, "v": KV_AXES}
        return spec, axes
    if cfg.family == "audio":
        sh = _kv_shape(cfg, cfg.num_layers, batch, max_len)
        xh = _kv_shape(cfg, cfg.num_layers, batch, cfg.encoder_seq)
        # cross-attention KV keeps its own (non-shardable, 1500-frame) axis
        x_axes = ("layers", "cache_batch", "cross_seq", "kv_heads",
                  "head_dim")
        spec = {"k": S(sh, dtype), "v": S(sh, dtype),
                "xk": S(xh, dtype), "xv": S(xh, dtype)}
        axes = {"k": KV_AXES, "v": KV_AXES, "xk": x_axes, "xv": x_axes}
        return spec, axes
    if cfg.family == "ssm":
        d_inner = 2 * cfg.d_model
        H = cfg.num_heads
        Dm = d_inner // H
        Ds = cfg.d_model // H
        spec, axes = {}, {}
        for i in range(cfg.num_layers):
            key = f"block_{i}"
            if i in cfg.slstm_at:
                st = (batch, H, Ds)
                spec[key] = {n: S(st, jnp.float32) for n in ("h", "c", "n", "m")}
                axes[key] = {n: ("cache_batch", "ssm_heads", None)
                             for n in ("h", "c", "n", "m")}
            else:
                spec[key] = {
                    "conv": S((batch, 3, d_inner), dtype),
                    "C": S((batch, H, Dm, Dm), jnp.float32),
                    "n": S((batch, H, Dm), jnp.float32),
                    "m": S((batch, H), jnp.float32),
                }
                axes[key] = {
                    "conv": ("cache_batch", None, "ssm_inner"),
                    "C": ("cache_batch", "ssm_heads", None, None),
                    "n": ("cache_batch", "ssm_heads", None),
                    "m": ("cache_batch", "ssm_heads"),
                }
        return spec, axes
    if cfg.family == "hybrid":
        n_units, m_per, tail = tf.zamba_layout(cfg)
        s_cfg = cfg.ssm or SSMConfig()
        d_inner = s_cfg.expand * cfg.d_model
        H = s_cfg.num_heads or d_inner // s_cfg.head_dim
        P, N, W1 = s_cfg.head_dim, s_cfg.state_dim, s_cfg.conv_width - 1

        def mamba_spec(*lead):
            la = (None,) * len(lead)
            sp = {
                "conv_x": S(lead + (batch, W1, d_inner), dtype),
                "conv_B": S(lead + (batch, W1, N), dtype),
                "conv_C": S(lead + (batch, W1, N), dtype),
                "ssm": S(lead + (batch, H, P, N), jnp.float32),
            }
            ax = {
                "conv_x": la + ("cache_batch", None, "ssm_inner"),
                "conv_B": la + ("cache_batch", None, "ssm_state"),
                "conv_C": la + ("cache_batch", None, "ssm_state"),
                "ssm": la + ("cache_batch", "ssm_heads", None, None),
            }
            return sp, ax

        mu_s, mu_a = mamba_spec(n_units, m_per)
        sh = _kv_shape(cfg, n_units, batch, max_len)
        spec = {"mamba_units": mu_s,
                "attn": {"k": S(sh, dtype), "v": S(sh, dtype)}}
        axes = {"mamba_units": mu_a,
                "attn": {"k": KV_AXES, "v": KV_AXES}}
        if tail:
            mt_s, mt_a = mamba_spec(tail)
            spec["mamba_tail"] = mt_s
            axes["mamba_tail"] = mt_a
        return spec, axes
    raise ValueError(cfg.family)


def zeros_like_spec(spec: Pytree) -> Pytree:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


# ------------------------------------------------------------ forward ------

def _decoder_inputs(params, batch, cfg: ArchConfig, pos):
    """Token embeddings (+ modality overlays, + learned positions)."""
    x = embed(params["embed"], batch["tokens"], cfg)
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(x.dtype)
        x = jax.lax.dynamic_update_slice(x, img, (0, 0, 0))
    if cfg.rope == "none" and cfg.max_position_embeddings:
        S = x.shape[1]
        table = params["pos_embed"]["embedding"]
        p0 = 0 if pos is None else pos
        pe = jax.lax.dynamic_slice_in_dim(table, p0, S, axis=0)
        x = x + pe.astype(x.dtype)
    return shard(x, "batch", "act_seq", "embed")


def forward(params, batch: Dict, cfg: ArchConfig, *, kind="train",
            cache=None, pos=None, last_only=False):
    """Returns (logits, new_cache, aux). For last_only, logits are (B,1,V)."""
    decode_ring = bool(cfg.sliding_window) and cache is not None and \
        cfg.family in ("dense", "moe", "vlm")
    x = _decoder_inputs(params, batch, cfg, pos)

    if cfg.family in ("dense", "moe", "vlm"):
        x, new_cache, aux = tf.dense_stack(
            params["layers"], x, cfg, cache=cache, pos=pos, kind=kind,
            decode_ring=decode_ring)
    elif cfg.family == "audio":
        if cache is not None and "frames" not in batch:
            xk, xv = cache["xk"], cache["xv"]          # decode: cached cross-KV
        else:
            enc = tf.whisper_encoder(params, batch["frames"], cfg, kind=kind)
            xk, xv = tf.whisper_cross_kv(params, enc, cfg)
        self_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
        x, new_self = tf.whisper_decoder(params, x, cfg, (xk, xv),
                                         cache=self_cache, pos=pos, kind=kind)
        new_cache = None if cache is None else \
            {"k": new_self["k"], "v": new_self["v"], "xk": xk, "xv": xv}
        aux = jnp.float32(0)
    elif cfg.family == "ssm":
        x, new_cache = tf.xlstm_stack(params, x, cfg, state=cache, kind=kind)
        aux = jnp.float32(0)
    elif cfg.family == "hybrid":
        x, new_cache, aux = tf.zamba_stack(params, x, cfg, cache=cache,
                                           pos=pos, kind=kind)
    else:
        raise ValueError(cfg.family)

    x = norm(params["ln_f"], x, cfg.norm)
    if last_only:
        x = x[:, -1:]
    logits = unembed(params, x, cfg)
    return logits, new_cache, aux


# ------------------------------------------------------------- Model -------

@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # -- parameters --
    def init(self, rng) -> Pytree:
        b = ParamBuilder(rng, self.cfg.pdtype)
        init_arch(b, self.cfg)
        return b.params

    def abstract(self) -> Tuple[Pytree, Pytree]:
        """(abstract param pytree, logical-axes pytree) — no allocation."""
        holder = {}

        def f():
            b = ParamBuilder(jax.random.PRNGKey(0), self.cfg.pdtype)
            init_arch(b, self.cfg)
            holder["axes"] = b.axes
            return b.params

        abs_params = jax.eval_shape(f)
        return abs_params, holder["axes"]

    # -- training --
    def loss(self, params, batch) -> Tuple[jax.Array, Dict]:
        logits, _, aux = forward(params, batch, self.cfg, kind="train")
        mask = batch.get("mask")
        if self.cfg.family == "vlm" and mask is None:
            S = batch["tokens"].shape[1]
            mask = jnp.broadcast_to(
                (jnp.arange(S) >= self.cfg.num_image_tokens)[None],
                batch["labels"].shape)
        ce = cross_entropy(logits, batch["labels"], mask)
        loss = ce + 0.01 * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    # -- serving --
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        spec, _ = cache_template(self.cfg, batch, max_len, dtype)
        return zeros_like_spec(spec)

    def prefill(self, params, batch, cache):
        """Populate cache from a full prompt; logits for the LAST position."""
        logits, new_cache, _ = forward(params, batch, self.cfg, kind="prefill",
                                       cache=cache, pos=0, last_only=True)
        return logits, new_cache

    def decode_step(self, params, tokens, cache, pos):
        """tokens: (B,1) int32; pos: scalar int32 — current write position."""
        logits, new_cache, _ = forward(params, {"tokens": tokens}, self.cfg,
                                       kind="decode", cache=cache, pos=pos)
        return logits, new_cache

    # -- dry-run specs --
    def input_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16,
                    cache_dtype=None) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins + logical axes for every model input."""
        S = jax.ShapeDtypeStruct
        cfg = self.cfg
        B, L = shape.global_batch, shape.seq_len
        # grad-accumulation: train inputs arrive pre-split (n_micro, mb, ...)
        # so no resharding is needed inside the step
        mb = shape.microbatch if (shape.kind == "train" and shape.microbatch
                                  and shape.microbatch < B) else 0
        lead = (B // mb, mb) if mb else (B,)
        lax = ((None, "batch") if mb else ("batch",))

        def toks(s):
            return (S(lead + (s,), jnp.int32), lax + (None,))

        out: Dict[str, Any] = {}
        if shape.kind == "train":
            out["tokens"] = toks(L)
            out["labels"] = toks(L)
        elif shape.kind == "prefill":
            out["tokens"] = toks(L)
        else:                                        # decode
            out["tokens"] = (S((B, 1), jnp.int32), ("batch", None))
            out["pos"] = (S((), jnp.int32), ())
        if cfg.family == "audio" and shape.kind in ("train", "prefill"):
            out["frames"] = (S(lead + (cfg.encoder_seq, cfg.d_model), dtype),
                             lax + (None, "embed"))
        if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
            out["image_embeds"] = (S(lead + (cfg.num_image_tokens,
                                             cfg.d_model), dtype),
                                   lax + (None, "embed"))
        if shape.kind in ("prefill", "decode"):
            spec, axes = cache_template(cfg, B, L, cache_dtype or dtype)
            out["cache"] = (spec, axes)
        return out


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)


def abstract_params(cfg: ArchConfig):
    return build_model(cfg).abstract()


def count_params(cfg: ArchConfig) -> int:
    abs_p, _ = abstract_params(cfg)
    return int(sum(x.size for x in jax.tree.leaves(abs_p)))


def param_partition_specs(cfg: ArchConfig, policy):
    """PartitionSpec pytree for params under a ShardingPolicy (incl. FSDP)."""
    from repro.parallel.sharding import fsdp_param_spec
    abs_p, axes = abstract_params(cfg)
    return jax.tree.map(
        lambda leaf, ax: fsdp_param_spec(policy, ax, leaf.shape),
        abs_p, axes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
