"""Attention: GQA with RoPE, KV caches (linear + ring/SWA), flash algorithm.

Two execution paths, selected by shape:
  * ``dense_attention`` — materialized-logits oracle (small sequences, tests).
  * ``flash_attention`` — blocked online-softmax with custom VJP. This is the
    XLA fallback with the same schedule as the Pallas TPU kernel
    (``repro.kernels.flash_attention``); on CPU dry-runs this path lowers.

Layout convention: q is head-grouped ``(B, S, K, G, H)`` (K = kv heads,
G = q-heads-per-kv-head) so GQA never materializes repeated K/V and the
TP sharding of either K or G stays a plain dim sharding.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import rope_apply
from repro.parallel.sharding import shard

NEG_INF = -1e30


def _block_mask(qpos, kpos, causal: bool, window: int, kv_valid):
    """Validity mask with explicit leading batch dim.

    qpos: (Q,) or (I, Q) query positions; kpos: (J,) key positions;
    kv_valid: None | scalar | (B,) count of valid kv slots.
    Returns (B_or_1, [I,] Q, J).
    """
    qp = qpos[..., None]                                  # (..., Q, 1)
    m = jnp.ones(qpos.shape + kpos.shape, bool)           # (..., Q, J)
    if causal:
        m &= kpos <= qp
    if window:
        m &= kpos > qp - window
    m = m[None]                                           # (1, ..., Q, J)
    if kv_valid is not None:
        kv = jnp.asarray(kv_valid)
        if kv.ndim == 0:
            m = m & (kpos < kv)
        else:                                             # per-batch (B,)
            valid = kpos[None, :] < kv[:, None]           # (B, J)
            valid = valid.reshape((kv.shape[0],)
                                  + (1,) * (m.ndim - 3) + (1, kpos.shape[0]))
            m = m & valid
    return m


# ----------------------------------------------------------- dense path ----

@jax.named_scope("dense_attention")
def dense_attention(q, k, v, *, causal=True, window=0, kv_valid=None,
                    q_offset=0, kpos=None):
    """q: (B,Sq,K,G,H); k,v: (B,Skv,K,H). Returns (B,Sq,K,G,H)."""
    B, Sq, K, G, H = q.shape
    Skv = k.shape[1]
    scale = H ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = q_offset + jnp.arange(Sq)
    if kpos is None:
        kpos = jnp.arange(Skv)
    mask = _block_mask(qpos, kpos, causal, window, kv_valid)   # (B|1,Sq,Skv)
    mask = mask[:, None, None]                                 # (B|1,1,1,Sq,Skv)
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    w = jnp.where(mask, w, 0.0)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


# ----------------------------------------------------------- flash path ----

def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 7, 8))
def _flash(q, k, v, causal, window, kv_valid, qpos0, block_q, block_k):
    out, _ = _flash_fwd_impl(q, k, v, causal, window, kv_valid, qpos0,
                             block_q, block_k)
    return out


@jax.named_scope("flash_attention")
def _flash_fwd_impl(q, k, v, causal, window, kv_valid, qpos0, bq, bk):
    B, Sq, K, G, H = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // bq, Skv // bk
    scale = H ** -0.5
    qb = q.reshape(B, nq, bq, K, G, H)
    kb = k.reshape(B, nk, bk, K, H)
    vb = v.reshape(B, nk, bk, K, H)
    qpos = (qpos0 + jnp.arange(Sq)).reshape(nq, bq)

    def body(carry, j):
        acc, m, l = carry
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        kpos = j * bk + jnp.arange(bk)
        # mixed-precision dot (bf16 in, f32 accum) via preferred_element_type
        # — explicit .astype(f32) casts get hoisted above the KV-cache
        # update by XLA and force full-cache convert round-trips per layer
        s = jnp.einsum("biqkgh,bjkh->bikgqj", qb, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask(qpos, kpos, causal, window, kv_valid)
        mask = mask[:, :, None, None]            # (B|1,I,1,1,Q,J)
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_new = jnp.maximum(m_new, NEG_INF)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bikgqj,bjkh->bikgqh", p.astype(v.dtype), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, nq, K, G, bq, H), jnp.float32)
    m0 = jnp.full((B, nq, K, G, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, K, G, bq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(B, Sq, K, G, H).astype(q.dtype)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))      # (B,nq,K,G,bq)
    return out, lse


def _flash_fwd(q, k, v, causal, window, kv_valid, qpos0, bq, bk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, kv_valid, qpos0, bq, bk)
    return out, (q, k, v, out, lse, kv_valid, qpos0)


@jax.named_scope("flash_attention")
def _flash_bwd(causal, window, bq, bk, res, dout):
    q, k, v, out, lse, kv_valid, qpos0 = res
    B, Sq, K, G, H = q.shape
    Skv = k.shape[1]
    nq, nk = Sq // bq, Skv // bk
    scale = H ** -0.5
    qb = q.reshape(B, nq, bq, K, G, H)
    kb = k.reshape(B, nk, bk, K, H)
    vb = v.reshape(B, nk, bk, K, H)
    dob = dout.reshape(B, nq, bq, K, G, H)
    ob = out.reshape(B, nq, bq, K, G, H)
    qpos = (qpos0 + jnp.arange(Sq)).reshape(nq, bq)
    # D_i = rowsum(dO * O)
    D = jnp.sum(dob.astype(jnp.float32) * ob.astype(jnp.float32), axis=-1)
    D = D.transpose(0, 1, 3, 4, 2)                # (B,nq,K,G,bq)

    def body(dq_acc, j):
        kj = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
        kpos = j * bk + jnp.arange(bk)
        s = jnp.einsum("biqkgh,bjkh->bikgqj", qb, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = _block_mask(qpos, kpos, causal, window, kv_valid)
        mask = mask[:, :, None, None]            # (B|1,I,1,1,Q,J)
        p = jnp.exp(s - lse[..., None])
        p = jnp.where(mask, p, 0.0)               # (B,I,K,G,Q,J)
        dp = jnp.einsum("biqkgh,bjkh->bikgqj", dob, vj,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - D[..., None]) * scale).astype(k.dtype)
        pl = p.astype(v.dtype)
        dq_j = jnp.einsum("bikgqj,bjkh->bikgqh", ds, kj,
                          preferred_element_type=jnp.float32)
        dk_j = jnp.einsum("bikgqj,biqkgh->bjkh", ds, qb,
                          preferred_element_type=jnp.float32)
        dv_j = jnp.einsum("bikgqj,biqkgh->bjkh", pl, dob,
                          preferred_element_type=jnp.float32)
        return dq_acc + dq_j, (dk_j, dv_j)

    dq0 = jnp.zeros((B, nq, K, G, bq, H), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, jnp.arange(nk))
    dq = dq.transpose(0, 1, 4, 2, 3, 5).reshape(B, Sq, K, G, H).astype(q.dtype)
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, Skv, K, H).astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, Skv, K, H).astype(v.dtype)
    return dq, dk, dv, None, None      # no grads for kv_valid / qpos0


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=0, kv_valid=None,
                    q_offset=0, block_q=512, block_k=512):
    """Blocked attention; pads S to block multiples, masks the padding."""
    B, Sq = q.shape[:2]
    Skv = k.shape[1]
    bq = min(block_q, max(16, Sq))
    bk = min(block_k, max(16, Skv))
    q, _ = _pad_to(q, 1, bq)
    k, _ = _pad_to(k, 1, bk)
    v, _ = _pad_to(v, 1, bk)
    if k.shape[1] != Skv and kv_valid is None:
        kv_valid = Skv
    out = _flash(q, k, v, causal, window, kv_valid, q_offset, bq, bk)
    return out[:, :Sq]


# ------------------------------------------------------ attention layer ----

_KV_Q_SCALE = 32.0    # int8 KV cache: fixed-point, ±4 range (post-RoPE K/V)


def _cache_store(dtype):
    """Writer into the KV cache; int8 caches quantize (fixed scale 1/32,
    documented in DESIGN — halves/quarters decode HBM traffic)."""
    def fn(x):
        if jnp.dtype(dtype) == jnp.int8:
            return jnp.clip(jnp.round(x.astype(jnp.float32) * _KV_Q_SCALE),
                            -127, 127).astype(jnp.int8)
        return x.astype(dtype)
    return fn


def _cache_load(c, compute_dtype):
    if c.dtype == jnp.int8:
        return (c.astype(compute_dtype)
                * jnp.asarray(1.0 / _KV_Q_SCALE, compute_dtype))
    return c


def _axes_tuple(rule):
    if rule is None:
        return ()
    return (rule,) if isinstance(rule, str) else tuple(rule)


def seq_sharded_decode(q, ck, cv, pos, policy, compute_dtype):
    """Sequence-parallel flash-decode (shard_map): the KV cache seq dim is
    sharded over the mesh; each shard computes a local online-softmax
    partial and the results combine with a cross-shard log-sum-exp — the
    same math as flash combine across tiles, lifted to the mesh level.
    Streams 1/n_shards of the cache per device with O(B·H·hd) comms.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = policy.mesh
    seq_axes = _axes_tuple(policy.rules.get("cache_seq"))
    n_sh = 1
    for a in seq_axes:
        n_sh *= mesh.shape[a]
    S_loc = ck.shape[1] // n_sh

    q_spec = policy.spec("batch", None, "kv_heads", "qgroup", "head_dim")
    kv_spec = policy.spec("cache_batch", "cache_seq", "kv_heads", "head_dim")

    def local(q_l, k_l, v_l):
        rank = jnp.int32(0)
        stride = 1
        for a in reversed(seq_axes):
            rank = rank + jax.lax.axis_index(a) * stride
            stride *= mesh.shape[a]
        local_valid = jnp.clip(pos + 1 - rank * S_loc, 0, S_loc)
        k_l = _cache_load(k_l, compute_dtype)
        v_l = _cache_load(v_l, compute_dtype)
        qp, _ = _pad_to(q_l, 1, 16)
        bk = min(512, S_loc)
        out_l, lse_l = _flash_fwd_impl(qp, k_l, v_l, False, 0, local_valid,
                                       0, 16, bk)
        out_l = out_l[:, :1].astype(jnp.float32)       # (B,1,K,G,H)
        lse_l = lse_l[..., :1]                         # (B,1,K,G,1)->(B,K,G,1)
        lse_l = lse_l[:, 0, :, :, 0][:, None]          # (B,1,K,G)
        m = lse_l
        for a in seq_axes:
            m = jax.lax.pmax(m, a)
        w = jnp.exp(lse_l - m)
        den = w
        num = out_l * w[..., None]
        for a in seq_axes:
            den = jax.lax.psum(den, a)
            num = jax.lax.psum(num, a)
        return (num / jnp.maximum(den, 1e-30)[..., None]).astype(compute_dtype)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(q_spec, kv_spec, kv_spec),
                   out_specs=q_spec,
                   check_rep=False)
    return fn(q, ck, cv)


def seq_sharded_cache_write(cache_arr, new_kv, pos, policy):
    """Owner-computes write of one decode token into a seq-sharded cache:
    the shard owning slot ``pos`` updates locally; everyone else no-ops.
    Zero communication (vs the all-gather XLA SPMD would insert)."""
    from jax.experimental.shard_map import shard_map

    mesh = policy.mesh
    seq_axes = _axes_tuple(policy.rules.get("cache_seq"))
    n_sh = 1
    for a in seq_axes:
        n_sh *= mesh.shape[a]
    S_loc = cache_arr.shape[1] // n_sh
    kv_spec = policy.spec("cache_batch", "cache_seq", "kv_heads", "head_dim")
    new_spec = policy.spec("cache_batch", None, "kv_heads", "head_dim")
    store = _cache_store(cache_arr.dtype)

    def write(c_l, kn):
        rank = jnp.int32(0)
        stride = 1
        for a in reversed(seq_axes):
            rank = rank + jax.lax.axis_index(a) * stride
            stride *= mesh.shape[a]
        lp = pos - rank * S_loc
        mine = (lp >= 0) & (lp < S_loc)
        lp_c = jnp.clip(lp, 0, S_loc - 1)
        cur = jax.lax.dynamic_slice_in_dim(c_l, lp_c, 1, axis=1)
        upd = jnp.where(mine, store(kn), cur)
        return jax.lax.dynamic_update_slice_in_dim(c_l, upd, lp_c, axis=1)

    fn = shard_map(write, mesh=mesh, in_specs=(kv_spec, new_spec),
                   out_specs=kv_spec, check_rep=False)
    return fn(cache_arr, new_kv)


def attention(p, x, cfg: ArchConfig, *, causal=True, cache=None,
              pos=None, cross_kv=None, rope_mode=None, window=None,
              decode_ring=False):
    """Full attention sub-layer: proj -> rope -> cache -> attend -> out proj.

    cache: None | dict(k=(B,Smax,K,H), v=..., plus ring metadata).
    pos: scalar int32 — current write offset (decode/prefill-with-cache).
    cross_kv: (k, v) for encoder-decoder cross attention (skips self kv).
    Returns (y, new_cache).
    """
    B, S, _ = x.shape
    K, G, H = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, cfg.hd
    rope_mode = cfg.rope if rope_mode is None else rope_mode
    window = cfg.sliding_window if window is None else window

    q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"].astype(x.dtype))
    q = shard(q, "batch", "attn_q_seq", "kv_heads", "qgroup", "head_dim")
    if cross_kv is None:
        k = jnp.einsum("bsd,dkh->bskh", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dkh->bskh", x, p["wv"].astype(x.dtype))
        k = shard(k, "batch", None, "kv_heads", "head_dim")
        v = shard(v, "batch", None, "kv_heads", "head_dim")
    else:
        k, v = cross_kv

    positions = jnp.arange(S) + (0 if pos is None else pos)
    q = rope_apply(q, positions, rope_mode)
    if cross_kv is None:
        k = rope_apply(k, positions, rope_mode)

    new_cache = cache
    kv_valid = None
    q_offset = 0 if pos is None else pos
    kpos = None
    if cache is not None and cross_kv is None:
        Smax = cache["k"].shape[1]
        store = _cache_store(cache["k"].dtype)
        if S > 1:
            # Prefill: attend over the fresh K/V (pos must be 0 — chunked
            # prefill is unsupported); write the cache for later decode.
            with jax.named_scope("kv_cache_update"):
                if Smax < S:                   # ring cache (SWA): keep tail
                    assert window and S % Smax == 0, \
                        "ring prefill needs S % window == 0"
                    ck = store(k[:, -Smax:])
                    cv = store(v[:, -Smax:])
                else:
                    ck = jax.lax.dynamic_update_slice(
                        cache["k"], store(k), (0, 0, 0, 0))
                    cv = jax.lax.dynamic_update_slice(
                        cache["v"], store(v), (0, 0, 0, 0))
                new_cache = dict(cache, k=ck, v=cv)
        elif decode_ring and window:
            # Ring buffer (SWA): slot s holds latest position ≡ s (mod Smax)
            with jax.named_scope("kv_cache_update"):
                slot = pos % Smax
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], store(k), (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], store(v), (0, slot, 0, 0))
                new_cache = dict(cache, k=ck, v=cv)
            slots = jnp.arange(Smax)
            kp = (pos // Smax) * Smax + slots
            kpos = jnp.where(kp > pos, kp - Smax, kp)
            out = dense_attention(q, _cache_load(ck, x.dtype),
                                  _cache_load(cv, x.dtype), causal=True,
                                  window=window, q_offset=pos, kpos=kpos)
            y = jnp.einsum("bskgh,kghd->bsd", out, p["wo"].astype(x.dtype))
            return shard(y, "batch", "act_seq", "embed"), new_cache
        else:
            from repro.parallel.sharding import current_policy
            pol = current_policy()
            seq_sharded = (pol is not None and pol.mesh is not None
                           and pol.rules.get("cache_seq"))
            if seq_sharded:
                # sequence-parallel decode: owner-computes write + local
                # flash partials + cross-shard LSE combine (see above)
                with jax.named_scope("kv_cache_update"):
                    ck = seq_sharded_cache_write(cache["k"], k, pos, pol)
                    cv = seq_sharded_cache_write(cache["v"], v, pos, pol)
                    new_cache = dict(cache, k=ck, v=cv)
                out = seq_sharded_decode(q, ck, cv, pos, pol, x.dtype)
                out = shard(out, "batch", "act_seq", "kv_heads", "qgroup",
                            "head_dim")
                y = jnp.einsum("bskgh,kghd->bsd", out,
                               p["wo"].astype(x.dtype))
                if "bo" in p:
                    y = y + p["bo"].astype(x.dtype)
                return shard(y, "batch", "act_seq", "embed"), new_cache
            with jax.named_scope("kv_cache_update"):
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], store(k), (0, pos, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], store(v), (0, pos, 0, 0))
                new_cache = dict(cache, k=ck, v=cv)
            k, v = _cache_load(ck, x.dtype), _cache_load(cv, x.dtype)
            kv_valid = pos + S

    Skv = k.shape[1]
    if max(S, Skv) <= 2048:
        out = dense_attention(q, k, v, causal=causal, window=window,
                              kv_valid=kv_valid, q_offset=q_offset)
    else:
        out = flash_attention(q, k, v, causal=causal, window=window,
                              kv_valid=kv_valid, q_offset=q_offset)
    out = shard(out, "batch", None, "kv_heads", "qgroup", "head_dim")
    y = jnp.einsum("bskgh,kghd->bsd", out, p["wo"].astype(x.dtype))
    if "bo" in p:
        y = y + p["bo"].astype(x.dtype)
    return shard(y, "batch", "act_seq", "embed"), new_cache


def init_attention(b, name: str, cfg: ArchConfig, stack: int = 0,
                   bias: bool = False):
    d, K = cfg.d_model, cfg.num_kv_heads
    G, H = cfg.num_heads // K, cfg.hd
    # head-structured layouts: fan-in is d_model (q/k/v) resp. all head dims
    # (o) — the builder's shape[-2] default would misread these
    s_in = d ** -0.5
    s_out = (K * G * H) ** -0.5
    with b.scope(name):
        b.add("wq", (d, K, G, H), ("embed", "kv_heads", "qgroup", "head_dim"),
              scale=s_in, stack=stack)
        b.add("wk", (d, K, H), ("embed", "kv_heads", "head_dim"),
              scale=s_in, stack=stack)
        b.add("wv", (d, K, H), ("embed", "kv_heads", "head_dim"),
              scale=s_in, stack=stack)
        b.add("wo", (K, G, H, d), ("kv_heads", "qgroup", "head_dim", "embed"),
              scale=s_out, stack=stack)
        if bias:
            b.add("bo", (d,), ("embed",), init="zeros", stack=stack)


def make_kv_cache(cfg: ArchConfig, batch: int, max_len: int, layers: int,
                  dtype=jnp.bfloat16, ring_window: int = 0):
    """Abstract-friendly KV cache pytree, stacked over layers."""
    size = min(max_len, ring_window) if ring_window else max_len
    shape = (layers, batch, size, cfg.num_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
