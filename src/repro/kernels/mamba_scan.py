"""Pallas TPU chunked-SSD (Mamba2) kernel.

One grid cell owns a (batch, head) pair and walks chunks sequentially
("arbitrary" dim), carrying the (P, N) state in VMEM scratch. Within a
chunk everything is MXU matmuls on (Q, ...) tiles: the intra-chunk
decay-masked C·Bᵀ scores, the chunk-summary state update, and the
state-readout — the same intra/inter decomposition as the pure-jnp
``repro.models.ssm.ssd_chunked`` but without materializing any (Q, Q)
tensor in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *, nc):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    a = a_ref[0, :, 0].astype(jnp.float32)           # (Q,)
    Bm = b_ref[0, :, :].astype(jnp.float32)          # (Q, N)
    Cm = c_ref[0, :, :].astype(jnp.float32)          # (Q, N)

    a_cs = jnp.cumsum(a)                              # (Q,)
    # intra-chunk: L[l,s] = exp(a_cs[l] - a_cs[s]) for s<=l
    seg = a_cs[:, None] - a_cs[None, :]
    Q = a.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(si <= li, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    y = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,P)
    # inter-chunk: read out previous state with decay from chunk start
    state = state_ref[...]                            # (P, N)
    y += jnp.exp(a_cs)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = y.astype(o_ref.dtype)
    # state update: decay to end-of-chunk
    decay_end = jnp.exp(a_cs[-1] - a_cs)              # (Q,)
    upd = jax.lax.dot_general(x, Bm * decay_end[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P,N)
    state_ref[...] = state * jnp.exp(a_cs[-1]) + upd


def ssd_scan(x, a, Bm, Cm, *, chunk=128, interpret=False):
    """x: (B, S, H, P) pre-scaled by dt; a: (B, S, H) log-decay;
    Bm/Cm: (B, S, N). Returns y (B, S, H, P) (state readout fused).
    S must divide by chunk."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, j: (b, j, h)),
            pl.BlockSpec((1, Q, N), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, 1, P), lambda b, h, j: (b, j, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, Bm, Cm)
