"""Pallas TPU flash-attention backward kernels.

Standard two-pass flash backward with the softmax statistics (lse) and
D = rowsum(dO ∘ O) precomputed by the wrapper:

  dQ pass — grid (B, K, G, nq, [nk arbitrary]): each q tile accumulates
      dQ_i += (P ∘ (dP - D)) · K_j over streamed k/v tiles,
      P = exp(S - lse), dP = dO · Vᵀ.
  dKV pass — grid (B, K, nk, [nq arbitrary]): each kv tile accumulates
      dK_j += (P ∘ (dP - D))ᵀ · Q_i and dV_j += Pᵀ · dO_i over streamed q
      tiles (the G group dim is folded into MXU rows).

Both passes skip fully-masked tiles via ``pl.when`` exactly like the
forward kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask(qpos_lo, kpos_lo, shape_qk, causal, window, kv_valid):
    qpos = qpos_lo + jax.lax.broadcasted_iota(jnp.int32, shape_qk, 0)
    kpos = kpos_lo + jax.lax.broadcasted_iota(jnp.int32, shape_qk, 1)
    m = jnp.ones(shape_qk, jnp.bool_)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    if kv_valid is not None:
        m &= kpos < kv_valid
    return m


def _live(qpos_lo, kpos_lo, bq, bk, causal, window, kv_valid):
    live = True
    if causal:
        live = kpos_lo <= qpos_lo + bq - 1
    if window:
        live = jnp.logical_and(live, kpos_lo + bk - 1 > qpos_lo - window)
    if kv_valid is not None:
        live = jnp.logical_and(live, kpos_lo < kv_valid)
    return live


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dq_ref,
               acc_ref, *, causal, window, kv_valid, bq, bk, nk, scale):
    j = pl.program_id(4)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos_lo = pl.program_id(3) * bq
    kpos_lo = j * bk

    @pl.when(_live(qpos_lo, kpos_lo, bq, bk, causal, window, kv_valid))
    def _compute():
        q = q_ref[0, :, 0, 0, :]
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        do = do_ref[0, :, 0, 0, :]
        lse = lse_ref[0, 0, 0, :]
        D = d_ref[0, 0, 0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _mask(qpos_lo, kpos_lo, s.shape, causal, window, kv_valid)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - D[:, None]) * scale
        acc_ref[...] += jax.lax.dot_general(
            ds.astype(k.dtype), k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == nk - 1)
    def _final():
        dq_ref[0, :, 0, 0, :] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *,
                causal, window, kv_valid, bq, bk, nq, G, scale):
    i = pl.program_id(3)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qpos_lo = i * bq
    kpos_lo = pl.program_id(2) * bk

    @pl.when(_live(qpos_lo, kpos_lo, bq, bk, causal, window, kv_valid))
    def _compute():
        # fold the G group dim into MXU rows: (bq*G, H)
        q = q_ref[0, :, 0, :, :].reshape(-1, q_ref.shape[-1])
        do = do_ref[0, :, 0, :, :].reshape(-1, do_ref.shape[-1])
        lse = lse_ref[0, 0, :, :].T.reshape(-1)          # (bq*G,)
        D = d_ref[0, 0, :, :].T.reshape(-1)
        k = k_ref[0, :, 0, :]
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        # row r of s corresponds to q position qpos_lo + r // G
        rows = s.shape[0]
        qpos = qpos_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // G
        kpos = kpos_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        m = jnp.ones(s.shape, jnp.bool_)
        if causal:
            m &= kpos <= qpos
        if window:
            m &= kpos > qpos - window
        if kv_valid is not None:
            m &= kpos < kv_valid
        p = jnp.where(m, jnp.exp(s - lse[:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - D[:, None]) * scale
        dv_acc[...] += jax.lax.dot_general(
            p.astype(do.dtype), do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            ds.astype(q.dtype), q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == nq - 1)
    def _final():
        dk_ref[0, :, 0, :] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, dout, *, causal=True, window=0,
                        kv_valid=None, block_q=512, block_k=512,
                        interpret=False):
    """Returns (dq, dk, dv). lse: (B,K,G,Sq) from the forward kernel."""
    B, Sq, K, G, H = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = H ** -0.5
    # D = rowsum(dO * O): cheap elementwise+reduce, computed outside
    D = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    D = D.transpose(0, 2, 3, 1)                         # (B,K,G,Sq)

    q_spec = pl.BlockSpec((1, bq, 1, 1, H),
                          lambda b, kh, g, i, j: (b, i, kh, g, 0))
    kv_spec = pl.BlockSpec((1, bk, 1, H),
                           lambda b, kh, g, i, j: (b, j, kh, 0))
    stat_spec = pl.BlockSpec((1, 1, 1, bq),
                             lambda b, kh, g, i, j: (b, kh, g, i))
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, causal=causal, window=window,
                          kv_valid=kv_valid, bq=bq, bk=bk, nk=nk,
                          scale=scale),
        grid=(B, K, G, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, stat_spec, stat_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, H), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",) * 4 + ("arbitrary",)),
        interpret=interpret,
    )(q, k, v, dout, lse, D)

    q_spec2 = pl.BlockSpec((1, bq, 1, G, H),
                           lambda b, kh, j, i: (b, i, kh, 0, 0))
    kv_spec2 = pl.BlockSpec((1, bk, 1, H),
                            lambda b, kh, j, i: (b, j, kh, 0))
    stat_spec2 = pl.BlockSpec((1, 1, G, bq),
                              lambda b, kh, j, i: (b, kh, 0, i))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, causal=causal, window=window,
                          kv_valid=kv_valid, bq=bq, bk=bk, nq=nq, G=G,
                          scale=scale),
        grid=(B, K, nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, stat_spec2,
                  stat_spec2],
        out_specs=[kv_spec2, kv_spec2],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, H), jnp.float32),
                        pltpu.VMEM((bk, H), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, dout, lse, D)
    return dq, dk, dv
