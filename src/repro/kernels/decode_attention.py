"""Pallas TPU flash-decode kernel: one query token vs a long (padded) KV
cache. The q tile is tiny, so all G group-queries of one kv head are folded
into MXU rows ((G, H) x (H, block_k)); kv tiles stream along the arbitrary
grid dim with validity masking against the current position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_k, nk):
    b, kh, j = (pl.program_id(n) for n in range(3))
    kv_valid = valid_ref[0]

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kpos_lo = j * block_k

    @pl.when(kpos_lo < kv_valid)
    def _compute():
        q = q_ref[0, 0, 0, :, :].astype(jnp.float32)      # (G, H)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, H)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = kpos_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_valid
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(q, k, v, kv_valid, *, block_k=512, interpret=False):
    """q: (B, 1, K, G, H); k/v: (B, Smax, K, H); kv_valid: int32 () or (1,)
    number of valid cache slots. Returns (B, 1, K, G, H)."""
    B, one, K, G, H = q.shape
    assert one == 1
    Smax = k.shape[1]
    bk = min(block_k, Smax)
    assert Smax % bk == 0
    nk = Smax // bk
    grid = (B, K, nk)
    kv_valid = jnp.asarray(kv_valid, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, block_k=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, G, H), lambda b, kh, j, *_: (b, 0, kh, 0, 0)),
                pl.BlockSpec((1, bk, 1, H), lambda b, kh, j, *_: (b, j, kh, 0)),
                pl.BlockSpec((1, bk, 1, H), lambda b, kh, j, *_: (b, j, kh, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, G, H),
                                   lambda b, kh, j, *_: (b, 0, kh, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, H), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, 1, K, G, H), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_valid, q, k, v)
    return out
