"""Pallas TPU flash-attention forward kernel.

TPU-native schedule (DESIGN §Hardware-adaptation): the GPU flash-attention
warp layout is replaced by an MXU-tile schedule — q tiles of (block_q, head
dim) stay resident in VMEM while k/v tiles stream HBM->VMEM along the
innermost ("arbitrary") grid dimension; the online-softmax running max /
normalizer / accumulator live in VMEM scratch. Causal and sliding-window
masks skip fully-masked k/v tiles via ``pl.when`` (no MXU work issued).

Layout: q (B, Sq, K, G, H), k/v (B, Skv, K, H) — GQA never materializes
repeated K/V; the q tile folds the G group dim into rows so the MXU matmul
is (block_q*G, H) x (H, block_k), hardware-aligned for H, block_k multiples
of 128.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *, causal, window, kv_valid,
                block_q, block_k, nk):
    b, kh, g, i, j = (pl.program_id(n) for n in range(5))

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qpos_lo = i * block_q
    kpos_lo = j * block_k
    # tile-level skip: causal (tile entirely above diagonal) and window
    # (tile entirely left of the band)
    live = True
    if causal:
        live = kpos_lo <= qpos_lo + block_q - 1
    if window:
        live = jnp.logical_and(live,
                               kpos_lo + block_k - 1 > qpos_lo - window)
    if kv_valid is not None:
        live = jnp.logical_and(live, kpos_lo < kv_valid)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, 0, :].astype(jnp.float32)      # (bq, H)
        k = k_ref[0, :, 0, :].astype(jnp.float32)         # (bk, H)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        scale = q.shape[-1] ** -0.5
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = qpos_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kpos_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        if kv_valid is not None:
            mask &= kpos < kv_valid
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, 0, :] = m_ref[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, causal=True, window=0, kv_valid=None,
                        block_q=512, block_k=512, interpret=False):
    """Returns (out (B,Sq,K,G,H), lse (B,K,G,Sq))."""
    B, Sq, K, G, H = q.shape
    Skv = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, "pad sequences to block multiples"
    nq, nk = Sq // bq, Skv // bk
    grid = (B, K, G, nq, nk)

    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, kv_valid=kv_valid,
        block_q=bq, block_k=bk, nk=nk)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, 1, H),
                         lambda b, kh, g, i, j: (b, i, kh, g, 0)),
            pl.BlockSpec((1, bk, 1, H),
                         lambda b, kh, g, i, j: (b, j, kh, 0)),
            pl.BlockSpec((1, bk, 1, H),
                         lambda b, kh, g, i, j: (b, j, kh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, 1, H),
                         lambda b, kh, g, i, j: (b, i, kh, g, 0)),
            pl.BlockSpec((1, 1, 1, bq),
                         lambda b, kh, g, i, j: (b, kh, g, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sq, K, G, H), q.dtype),
            jax.ShapeDtypeStruct((B, K, G, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, H), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out, lse
