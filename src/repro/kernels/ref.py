"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, kv_valid=None):
    """q: (B,Sq,K,G,H); k/v: (B,Skv,K,H) — materialized softmax attention."""
    B, Sq, K, G, H = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqkgh,bskh->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (H ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_valid is not None:
        mask &= kpos < kv_valid
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_ref(q, k, v, kv_valid):
    """q: (B,1,K,G,H); k/v: (B,Smax,K,H)."""
    return attention_ref(q, k, v, causal=False, window=0, kv_valid=kv_valid)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_residual_ref(x, residual, scale, eps=1e-6):
    s = (x.astype(jnp.float32) + residual.astype(jnp.float32))
    return rmsnorm_ref(s, scale, eps).astype(x.dtype), s.astype(x.dtype)


def ssd_ref(x, a, Bm, Cm):
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x: (B,S,H,P) pre-scaled by dt; a: (B,S,H) log decay; Bm/Cm: (B,S,N).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, t):
        xt, at, bt, ct = t
        state = state * jnp.exp(at)[..., None, None] \
            + jnp.einsum("bhp,bn->bhpn", xt, bt)
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    init = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          a.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)      # (B,S,H,P)
