"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) so every wrapper is
runnable/testable on CPU; on TPU backends the kernels lower natively. The
flash-attention backward pass reuses the blocked XLA implementation from
``repro.models.attention`` (same math as the fwd kernel's schedule) — a
Pallas bwd kernel is listed as future work in DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import flash_decode as _flash_decode
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.mamba_scan import ssd_scan as _ssd_scan
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.rmsnorm import rmsnorm_residual as _rmsnorm_residual


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "kv_valid",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, kv_valid=None,
                    block_q=512, block_k=512):
    out, _ = flash_attention_fwd(
        q, k, v, causal=causal, window=window, kv_valid=kv_valid,
        block_q=block_q, block_k=block_k, interpret=_default_interpret())
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_diff(q, k, v, causal=True, window=0, kv_valid=None,
                         block_q=512, block_k=512):
    """Differentiable flash attention: Pallas forward AND backward kernels
    (``flash_attention_bwd``)."""
    out, _ = flash_attention_fwd(
        q, k, v, causal=causal, window=window, kv_valid=kv_valid,
        block_q=block_q, block_k=block_k, interpret=_default_interpret())
    return out


def _fa_fwd(q, k, v, causal, window, kv_valid, block_q, block_k):
    out, lse = flash_attention_fwd(
        q, k, v, causal=causal, window=window, kv_valid=kv_valid,
        block_q=block_q, block_k=block_k, interpret=_default_interpret())
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, window, kv_valid, block_q, block_k, res, dout):
    from repro.kernels.flash_attention_bwd import flash_attention_bwd
    q, k, v, out, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, out, lse, dout, causal=causal, window=window,
        kv_valid=kv_valid, block_q=block_q, block_k=block_k,
        interpret=_default_interpret())
    return dq, dk, dv


flash_attention_diff.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(jax.jit, static_argnames=("block_k",))
def flash_decode(q, k, v, kv_valid, *, block_k=512):
    return _flash_decode(q, k, v, kv_valid, block_k=block_k,
                         interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=256):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = _rmsnorm(x2, scale, eps=eps, block_rows=block_rows,
                 interpret=_default_interpret())
    return y.reshape(shape)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm_residual(x, residual, scale, *, eps=1e-6, block_rows=256):
    shape = x.shape
    y, r = _rmsnorm_residual(x.reshape(-1, shape[-1]),
                             residual.reshape(-1, shape[-1]), scale,
                             eps=eps, block_rows=block_rows,
                             interpret=_default_interpret())
    return y.reshape(shape), r.reshape(shape)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, a, Bm, Cm, *, chunk=128):
    return _ssd_scan(x, a, Bm, Cm, chunk=chunk,
                     interpret=_default_interpret())
