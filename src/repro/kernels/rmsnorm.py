"""Pallas TPU fused RMSNorm (+ optional residual add) kernel.

Row tiles of (block_rows, d) are normalized entirely in VMEM: one HBM read
of x (+residual), one write — where the unfused XLA chain reads/writes x
three times (square-mean, rsqrt-scale, multiply).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _rmsnorm_residual_kernel(x_ref, r_ref, scale_ref, o_ref, res_ref, *, eps):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = x.astype(res_ref.dtype)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps=1e-6, block_rows=256, interpret=False):
    """x: (rows, d) [reshape higher-rank inputs first]; scale: (d,)."""
    rows, d = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, scale)


def rmsnorm_residual(x, residual, scale, *, eps=1e-6, block_rows=256,
                     interpret=False):
    """Fused (x + residual) -> (normed, new_residual)."""
    rows, d = x.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    kernel = functools.partial(_rmsnorm_residual_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows, d), x.dtype),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, residual, scale)
