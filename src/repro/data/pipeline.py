"""Deterministic, stateless, sharded synthetic-LM data pipeline.

Every batch is a pure function of (seed, step) — no iterator state — so a
restarted worker resumes bit-identically from any checkpointed step (the
fault-tolerance contract). Token streams follow a Zipf-like marginal with a
deterministic next-token structure so the cross-entropy actually decreases
during the e2e example runs (the model has something learnable).

Batches are produced pre-split as (n_micro, mb, S) when a microbatch is
configured, matching ``Model.input_specs`` so no resharding happens on
device.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLM:
    cfg: ArchConfig
    shape: ShapeConfig
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jax.Array]:
        cfg, shape = self.cfg, self.shape
        B, S = shape.global_batch, shape.seq_len
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        V = cfg.vocab_size
        # Zipf-ish marginal via squared uniform; learnable structure:
        # next token = (3 * tok + 7) % V with prob 0.8
        u = jax.random.uniform(k1, (B, S + 1))
        base = (u * u * (V - 1)).astype(jnp.int32)
        prev = jnp.roll(base, 1, axis=1)
        det = (3 * prev + 7) % V
        pick = jax.random.uniform(k2, (B, S + 1)) < 0.8
        toks = jnp.where(pick, det, base)
        batch = {"tokens": toks[:, :S], "labels": toks[:, 1:]}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                k1, (B, cfg.encoder_seq, cfg.d_model), jnp.float32
            ).astype(cfg.adtype)
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.random.normal(
                k2, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32
            ).astype(cfg.adtype)
        mb = shape.microbatch
        if shape.kind == "train" and mb and mb < B:
            n = B // mb
            batch = {k: v.reshape((n, mb) + v.shape[1:])
                     for k, v in batch.items()}
        return batch


def make_batch_fn(cfg: ArchConfig, shape: ShapeConfig, seed: int = 0):
    ds = SyntheticLM(cfg, shape, seed)
    return ds.batch
