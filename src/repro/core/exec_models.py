"""The paper's three execution models for scientific workflows on Kubernetes.

1. JobExecutor        — one Kubernetes Job (one Pod) per task (§3.2).
2. ClusteredExecutor  — job model + horizontal task clustering: batches of
                        `size` same-type tasks run sequentially in one Pod,
                        flushed after `timeout_ms` if incomplete (§3.5).
3. WorkerPoolExecutor — the paper's contribution (§3.3): one auto-scalable
                        worker pool (deployment + queue) per task type, with
                        queue-length-driven, workload-proportional scaling
                        and KEDA scale-to-zero. A *hybrid* mode (used in the
                        paper's §4.4 evaluation) runs only the parallel-stage
                        task types in pools and everything else as jobs.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Sequence

from repro.core.autoscaler import (HPA_SYNC_PERIOD, SCALE_DOWN_STABILIZATION,
                                   proportional_replicas)
from repro.core.cluster import ClusterSim, Pod
from repro.core.workflow import Task


class JobExecutor:
    """Each task -> one Job -> one Pod (created, runs task, destroyed)."""

    def __init__(self):
        self.engine = None
        self.sim: Optional[ClusterSim] = None

    def bind(self, engine, sim: ClusterSim):
        self.engine, self.sim = engine, sim

    def submit(self, task: Task):
        def on_started(sim: ClusterSim, pod: Pod):
            task.started_at = sim.t
            sim.task_started(task.cpu)

            def complete():
                sim.task_finished(task.cpu)
                sim.delete_pod(pod.id)
                self.engine.on_task_done(task)

            sim.schedule(task.duration, complete)

        self.sim.submit_pod(f"job-{task.type}-{task.id}", task.cpu, task.mem,
                            on_started)


class ClusteredExecutor:
    """Job model with horizontal task clustering (same-type, sequential)."""

    def __init__(self, rules: Dict[str, dict] | None = None,
                 default_size: int = 1, default_timeout_ms: float = 3000.0):
        # rules: {taskType: {"size": int, "timeoutMs": float}} — mirrors the
        # HyperFlow agglomeration config file shown in the paper.
        self.rules = rules or {}
        self.default_size = default_size
        self.default_timeout_ms = default_timeout_ms
        self.buffers: Dict[str, List[Task]] = collections.defaultdict(list)
        self.flush_deadline: Dict[str, float] = {}
        self.engine = None
        self.sim: Optional[ClusterSim] = None

    def bind(self, engine, sim: ClusterSim):
        self.engine, self.sim = engine, sim

    def _rule(self, task_type: str):
        r = self.rules.get(task_type, {})
        return (int(r.get("size", self.default_size)),
                float(r.get("timeoutMs", self.default_timeout_ms)) / 1000.0)

    def submit(self, task: Task):
        size, timeout = self._rule(task.type)
        if size <= 1:
            JobExecutor.submit(self, task)          # same pod-per-task path
            return
        buf = self.buffers[task.type]
        buf.append(task)
        if len(buf) >= size:
            self._flush(task.type)
        elif len(buf) == 1:
            deadline = self.sim.t + timeout
            self.flush_deadline[task.type] = deadline
            self.sim.schedule(timeout, self._timeout_flush, task.type, deadline)

    def _timeout_flush(self, task_type: str, deadline: float):
        if self.flush_deadline.get(task_type) == deadline \
                and self.buffers[task_type]:
            self._flush(task_type)

    def _flush(self, task_type: str):
        batch = self.buffers[task_type]
        self.buffers[task_type] = []
        self.flush_deadline.pop(task_type, None)
        if not batch:
            return
        cpu = max(t.cpu for t in batch)
        mem = max(t.mem for t in batch)

        def on_started(sim: ClusterSim, pod: Pod):
            def run_next(i: int):
                if i >= len(batch):
                    sim.delete_pod(pod.id)
                    return
                t = batch[i]
                t.started_at = sim.t
                sim.task_started(t.cpu)

                def complete():
                    sim.task_finished(t.cpu)
                    self.engine.on_task_done(t)
                    run_next(i + 1)

                sim.schedule(t.duration, complete)

            run_next(0)

        self.sim.submit_pod(f"clustered-{task_type}-x{len(batch)}", cpu, mem,
                            on_started)


class _Pool:
    def __init__(self, task_type: str, cpu: float, mem: float):
        self.type = task_type
        self.cpu, self.mem = cpu, mem
        self.queue: Deque[Task] = collections.deque()
        self.workers: Dict[int, Pod] = {}       # pod_id -> Pod
        self.idle: Deque[int] = collections.deque()
        self.in_flight = 0
        self.scale_down_since: Optional[float] = None

    def demand(self) -> float:
        return len(self.queue) + self.in_flight


class WorkerPoolExecutor:
    """Worker pools with queue-driven proportional auto-scaling.

    pooled_types=None -> a pool per task type (pure model); a sequence ->
    hybrid model (paper §4.4): those types pooled, the rest run as jobs.
    """

    def __init__(self, pooled_types: Optional[Sequence[str]] = None,
                 sync_period: float = HPA_SYNC_PERIOD,
                 cooldown: float = SCALE_DOWN_STABILIZATION,
                 job_headroom: float = 2.0):
        self.pooled_types = set(pooled_types) if pooled_types else None
        self.sync_period = sync_period
        self.cooldown = cooldown
        self.job_headroom = job_headroom        # cores left for job-model tasks
        self.pools: Dict[str, _Pool] = {}
        self.engine = None
        self.sim: Optional[ClusterSim] = None
        self._tick_scheduled = False
        self.scale_events: List = []

    def bind(self, engine, sim: ClusterSim):
        self.engine, self.sim = engine, sim

    # ------------------------------------------------------------ submit --
    def submit(self, task: Task):
        if self.pooled_types is not None and task.type not in self.pooled_types:
            JobExecutor.submit(self, task)      # hybrid: job path
            return
        pool = self.pools.get(task.type)
        if pool is None:
            pool = self.pools[task.type] = _Pool(task.type, task.cpu, task.mem)
        pool.queue.append(task)
        self._dispatch(pool)
        self._ensure_tick()

    # ---------------------------------------------------------- dispatch --
    def _dispatch(self, pool: _Pool):
        while pool.queue and pool.idle:
            pod_id = pool.idle.popleft()
            pod = pool.workers.get(pod_id)
            if pod is None or pod.state != "running":
                continue
            task = pool.queue.popleft()
            self._run_on(pool, pod, task)

    def _run_on(self, pool: _Pool, pod: Pod, task: Task):
        sim = self.sim
        pool.in_flight += 1
        pod.busy = True
        task.started_at = sim.t
        sim.task_started(task.cpu)

        def complete():
            sim.task_finished(task.cpu)
            pool.in_flight -= 1
            pod.busy = False
            self.engine.on_task_done(task)
            if getattr(pod, "draining", False):
                # cooperative preemption at the task boundary (graceful
                # termination): release the node for the pool that is owed it
                sim.delete_pod(pod.id)
                pool.workers.pop(pod.id, None)
            elif pool.queue and pod.state == "running":
                nxt = pool.queue.popleft()
                self._run_on(pool, pod, nxt)
            elif pod.state == "running":
                pool.idle.append(pod.id)

        sim.schedule(task.duration, complete)

    # --------------------------------------------------------- autoscale --
    def _ensure_tick(self):
        if not self._tick_scheduled:
            self._tick_scheduled = True
            self.sim.schedule(self.sync_period, self._tick)

    def _tick(self):
        self._tick_scheduled = False
        sim = self.sim
        demand = {p.type: p.demand() for p in self.pools.values()}
        cpu_req = {p.type: p.cpu for p in self.pools.values()}
        quota = sim.capacity_cores() - self.job_headroom
        desired = proportional_replicas(demand, cpu_req, quota)
        have = {p.type: sum(1 for w in p.workers.values()
                            if w.state in ("pending", "starting", "running")
                            and not getattr(w, "draining", False))
                for p in self.pools.values()}
        # contention: some pool is owed workers it cannot get from free space
        shortfall = sum(max(0, desired[t] - have[t]) * cpu_req[t]
                        for t in desired)
        contention = shortfall > sim.free_cores() + 1e-9
        for pool in self.pools.values():
            want, got = desired.get(pool.type, 0), have[pool.type]
            if want > got:
                pool.scale_down_since = None
                need = want - got
                # cancel draining workers first — cheaper than new pods
                for pod in pool.workers.values():
                    if need and getattr(pod, "draining", False) \
                            and pod.state == "running":
                        pod.draining = False
                        need -= 1
                for _ in range(need):
                    self._add_worker(pool)
                self.scale_events.append((sim.t, pool.type, got, want))
            elif want < got:
                # KEDA-style cooldown before scaling down / to zero — but the
                # proportional-allocation contract overrides it when another
                # pool is starved (the paper's intertwined-stages requirement)
                if contention:
                    self._remove_workers(pool, got - want)
                    pool.scale_down_since = None
                    self.scale_events.append((sim.t, pool.type, got, want))
                elif pool.scale_down_since is None:
                    pool.scale_down_since = sim.t
                elif sim.t - pool.scale_down_since >= self.cooldown:
                    self._remove_workers(pool, got - want)
                    pool.scale_down_since = None
                    self.scale_events.append((sim.t, pool.type, got, want))
            else:
                pool.scale_down_since = None
        if any(p.demand() > 0 or p.workers for p in self.pools.values()):
            self._ensure_tick()

    def _add_worker(self, pool: _Pool):
        def on_started(sim: ClusterSim, pod: Pod):
            pool.idle.append(pod.id)
            self._dispatch(pool)

        pod = self.sim.submit_pod(f"pool-{pool.type}", pool.cpu, pool.mem,
                                  on_started)
        pool.workers[pod.id] = pod

    def _remove_workers(self, pool: _Pool, n: int):
        # prefer idle workers, then pending ones; busy workers are marked
        # draining and exit at the next task boundary
        victims = [pid for pid in list(pool.idle)][:n]
        if len(victims) < n:
            victims += [p.id for p in pool.workers.values()
                        if p.state == "pending"][:n - len(victims)]
        for pid in victims:
            self.sim.delete_pod(pid)
            pool.workers.pop(pid, None)
            try:
                pool.idle.remove(pid)
            except ValueError:
                pass
        left = n - len(victims)
        if left > 0:
            for pod in pool.workers.values():
                if left <= 0:
                    break
                if pod.busy and not getattr(pod, "draining", False):
                    pod.draining = True
                    left -= 1

    def shutdown(self):
        for pool in self.pools.values():
            for pid in list(pool.workers):
                self.sim.delete_pod(pid)
            pool.workers.clear()
