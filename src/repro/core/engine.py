"""HyperFlow-style enactment engine: walks the workflow DAG and hands ready
tasks to an executor; executors call back on completion. Engine/executor
separation mirrors hyperflow + hyperflow-job-executor in the paper."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.cluster import ClusterSim
from repro.core.workflow import Task, Workflow


@dataclasses.dataclass
class RunReport:
    makespan: float
    utilization: float
    pods_created: int
    n_tasks: int
    critical_path: float
    total_work: float
    sched_attempts: int
    per_type: Dict[str, int]

    def row(self) -> str:
        return (f"makespan={self.makespan:.0f}s util={self.utilization:.3f} "
                f"pods={self.pods_created} tasks={self.n_tasks}")


class HyperflowEngine:
    def __init__(self, workflow: Workflow, executor, sim: ClusterSim):
        self.wf = workflow
        self.executor = executor
        self.sim = sim
        executor.bind(self, sim)

    def start(self):
        for t in self.wf.roots():
            t.submitted_at = self.sim.t
            self.executor.submit(t)

    def on_task_done(self, task: Task):
        for nt in self.wf.complete(task.id, self.sim.t):
            nt.submitted_at = self.sim.t
            self.executor.submit(nt)

    def run(self, until: Optional[float] = None) -> RunReport:
        self.start()
        self.sim.run(until=until, stop_when=self.wf.all_done)
        if hasattr(self.executor, "shutdown"):
            self.executor.shutdown()
        makespan = max((t.finished_at or 0.0) for t in self.wf.tasks.values())
        return RunReport(
            makespan=makespan,
            utilization=self.sim.utilization(makespan),
            pods_created=self.sim.pods_created,
            n_tasks=len(self.wf),
            critical_path=self.wf.critical_path(),
            total_work=self.wf.total_work(),
            sched_attempts=self.sim.sched_attempts,
            per_type=self.wf.task_types(),
        )
