"""KEDA/HPA-style autoscaler with workload-proportional resource allocation.

Implements the paper's §3.5 scaling rule: the desired replica count of each
competing worker pool is computed so that cluster resources are allocated
proportionally to each pool's current workload (queue length x per-task CPU
request), subject to the cluster quota; pools with empty queues scale to
zero (KEDA), which plain HPA cannot do.
"""
from __future__ import annotations

import math
from typing import Dict, Mapping

HPA_SYNC_PERIOD = 15.0            # Kubernetes HPA default sync period
SCALE_DOWN_STABILIZATION = 30.0   # KEDA cooldown before releasing workers


def proportional_replicas(demand: Mapping[str, float],
                          cpu_request: Mapping[str, float],
                          quota_cores: float,
                          min_share: float = 0.0) -> Dict[str, int]:
    """Compute desired replicas per pool.

    demand[p]: outstanding work for pool p, in tasks (queued + in-flight).
    cpu_request[p]: cores per worker replica of pool p.
    quota_cores: total cores the pools may occupy.

    If total demand fits in the quota every pool gets ceil(demand) replicas;
    otherwise the quota is split proportionally to core-demand (the paper's
    proportional-allocation requirement), largest-remainder rounded so the
    quota is used fully but never exceeded.
    """
    want_cores = {p: demand[p] * cpu_request[p] for p in demand}
    total = sum(want_cores.values())
    if total <= 0:
        return {p: 0 for p in demand}
    if total <= quota_cores:
        return {p: int(math.ceil(demand[p])) for p in demand}
    shares = {p: quota_cores * want_cores[p] / total for p in demand}
    # at least min_share cores for any pool with demand (avoid starvation)
    if min_share:
        for p in shares:
            if demand[p] > 0:
                shares[p] = max(shares[p], min_share)
    # largest-remainder rounding in units of replicas
    repl = {p: int(shares[p] / cpu_request[p]) for p in demand}
    used = sum(repl[p] * cpu_request[p] for p in demand)
    rema = sorted(demand, key=lambda p: (shares[p] / cpu_request[p]) % 1.0,
                  reverse=True)
    for p in rema:
        if used + cpu_request[p] <= quota_cores and repl[p] < demand[p]:
            repl[p] += 1
            used += cpu_request[p]
    # never exceed what the pool can use
    for p in repl:
        repl[p] = min(repl[p], int(math.ceil(demand[p])))
    return repl
