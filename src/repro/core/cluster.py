"""Deterministic discrete-event Kubernetes cluster simulator.

Models the control-plane behaviours the paper measures:
  * Pod creation latency (~2 s container start, paper §4.2),
  * scheduler retry with exponential back-off for Pending pods
    (initial 10 s, x2, cap 300 s — "up to several minutes", §4.2),
  * API-server/scheduler throughput limits (attempts per cycle), which
    overload under thousands of concurrently-requested pods,
  * resource-request-based first-fit placement (CPU + memory),
  * immediate resource release on pod termination.

The key asymmetry the paper exploits: freed capacity is only picked up by a
Pending pod when *its* back-off timer expires — long-lived worker-pool pods
never pay that price after the initial scale-up.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from typing import Callable, Dict, List, Optional

POD_STARTUP = 2.0
BACKOFF_INITIAL = 10.0
BACKOFF_FACTOR = 2.0
BACKOFF_MAX = 300.0
SCHED_INTERVAL = 1.0
SCHED_ATTEMPTS_PER_CYCLE = 100     # scheduler throughput bound


@dataclasses.dataclass
class Node:
    id: int
    cpu: float
    mem: float
    used_cpu: float = 0.0
    used_mem: float = 0.0

    def fits(self, cpu: float, mem: float) -> bool:
        return (self.used_cpu + cpu <= self.cpu + 1e-9
                and self.used_mem + mem <= self.mem + 1e-9)


@dataclasses.dataclass
class Pod:
    id: int
    name: str
    cpu: float
    mem: float
    on_started: Optional[Callable] = None   # fn(sim, pod)
    node: Optional[int] = None
    state: str = "pending"                  # pending|starting|running|done
    submitted_at: float = 0.0
    started_at: Optional[float] = None
    backoff: float = BACKOFF_INITIAL
    next_attempt: float = 0.0
    busy: bool = False                      # executing a task right now


class ClusterSim:
    def __init__(self, n_nodes: int = 17, node_cpu: float = 4.0,
                 node_mem: float = 16384.0, seed: int = 0,
                 pod_startup: float = POD_STARTUP,
                 sched_interval: float = SCHED_INTERVAL,
                 attempts_per_cycle: int = SCHED_ATTEMPTS_PER_CYCLE,
                 backoff_initial: float = BACKOFF_INITIAL,
                 backoff_max: float = BACKOFF_MAX):
        self.nodes = [Node(i, node_cpu, node_mem) for i in range(n_nodes)]
        self.t = 0.0
        self.rng = random.Random(seed)
        self.pod_startup = pod_startup
        self.sched_interval = sched_interval
        self.attempts_per_cycle = attempts_per_cycle
        self.backoff_initial = backoff_initial
        self.backoff_max = backoff_max
        self._heap: List = []
        self._seq = itertools.count()
        self._pod_ids = itertools.count()
        self.pods: Dict[int, Pod] = {}
        self.pending: List[int] = []
        self.pods_created = 0
        self.sched_cycles = 0
        self.sched_attempts = 0
        # metrics: step functions over time
        self.busy_cores_trace: List = [(0.0, 0.0)]
        self.running_tasks_trace: List = [(0.0, 0)]
        self.pending_trace: List = [(0.0, 0)]
        self._busy_cores = 0.0
        self._running_tasks = 0
        self._sched_timer_set = False

    # ------------------------------------------------------------ events --
    def schedule(self, delay: float, fn: Callable, *args):
        heapq.heappush(self._heap, (self.t + delay, next(self._seq), fn, args))

    def _record(self):
        self.busy_cores_trace.append((self.t, self._busy_cores))
        self.running_tasks_trace.append((self.t, self._running_tasks))
        self.pending_trace.append((self.t, len(self.pending)))

    def capacity_cores(self) -> float:
        return sum(n.cpu for n in self.nodes)

    def free_cores(self) -> float:
        return sum(n.cpu - n.used_cpu for n in self.nodes)

    # -------------------------------------------------------------- pods --
    def submit_pod(self, name: str, cpu: float, mem: float,
                   on_started: Callable) -> Pod:
        pod = Pod(next(self._pod_ids), name, cpu, mem, on_started,
                  submitted_at=self.t, next_attempt=self.t,
                  backoff=self.backoff_initial)
        self.pods[pod.id] = pod
        self.pending.append(pod.id)
        self.pods_created += 1
        self._ensure_sched_timer()
        self._record()
        return pod

    def delete_pod(self, pod_id: int):
        pod = self.pods.get(pod_id)
        if pod is None or pod.state == "done":
            return
        if pod.state in ("starting", "running") and pod.node is not None:
            node = self.nodes[pod.node]
            node.used_cpu -= pod.cpu
            node.used_mem -= pod.mem
        if pod.state == "pending" and pod.id in self.pending:
            self.pending.remove(pod.id)
        pod.state = "done"
        self._record()

    def task_started(self, cores: float):
        self._busy_cores += cores
        self._running_tasks += 1
        self._record()

    def task_finished(self, cores: float):
        self._busy_cores -= cores
        self._running_tasks -= 1
        self._record()

    # --------------------------------------------------------- scheduler --
    def _ensure_sched_timer(self):
        if not self._sched_timer_set:
            self._sched_timer_set = True
            self.schedule(self.sched_interval, self._sched_cycle)

    def _sched_cycle(self):
        self._sched_timer_set = False
        self.sched_cycles += 1
        attempts = 0
        still: List[int] = []
        # FIFO over pods whose back-off has expired; bounded throughput
        for pid in self.pending:
            pod = self.pods[pid]
            if pod.state != "pending":
                continue
            if pod.next_attempt > self.t or attempts >= self.attempts_per_cycle:
                still.append(pid)
                continue
            attempts += 1
            node = self._first_fit(pod)
            if node is None:
                pod.backoff = min(pod.backoff * BACKOFF_FACTOR,
                                  self.backoff_max)
                pod.next_attempt = self.t + pod.backoff * self.rng.uniform(0.9, 1.1)
                still.append(pid)
            else:
                node.used_cpu += pod.cpu
                node.used_mem += pod.mem
                pod.node = node.id
                pod.state = "starting"
                self.schedule(self.pod_startup, self._pod_started, pod.id)
        self.sched_attempts += attempts
        self.pending = still
        self._record()
        if self.pending:
            self._ensure_sched_timer()

    def _first_fit(self, pod: Pod) -> Optional[Node]:
        allowed = getattr(pod, "allowed_nodes", None)
        for node in self.nodes:
            if allowed is not None and node.id not in allowed:
                continue
            if node.fits(pod.cpu, pod.mem):
                return node
        return None

    def _pod_started(self, pod_id: int):
        pod = self.pods[pod_id]
        if pod.state != "starting":
            return
        pod.state = "running"
        pod.started_at = self.t
        if pod.on_started:
            pod.on_started(self, pod)

    # --------------------------------------------------------------- run --
    def run(self, until: Optional[float] = None,
            stop_when: Optional[Callable[[], bool]] = None,
            max_events: int = 50_000_000):
        events = 0
        while self._heap:
            if stop_when and stop_when():
                break
            t, _, fn, args = heapq.heappop(self._heap)
            if until is not None and t > until:
                self.t = until
                break
            self.t = t
            fn(*args)
            events += 1
            if events >= max_events:
                raise RuntimeError("simulator event budget exceeded")
        return self.t

    # ------------------------------------------------------------ report --
    def utilization(self, t_end: Optional[float] = None) -> float:
        """Time-averaged busy-cores / capacity over [0, t_end]."""
        trace = self.busy_cores_trace
        t_end = t_end if t_end is not None else self.t
        if t_end <= 0:
            return 0.0
        area = 0.0
        for (t0, v), (t1, _) in zip(trace, trace[1:]):
            area += v * (min(t1, t_end) - min(t0, t_end))
        area += trace[-1][1] * max(0.0, t_end - trace[-1][0])
        return area / (self.capacity_cores() * t_end)
