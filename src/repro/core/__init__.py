"""Layer A — the paper's contribution: HyperFlow-style workflow engine,
Kubernetes cluster simulator, and the three execution models
(job / job+clustering / worker-pools with proportional auto-scaling)."""
from repro.core.workflow import Task, Workflow
from repro.core.montage import montage, montage_small
from repro.core.cluster import ClusterSim
from repro.core.engine import HyperflowEngine, RunReport
from repro.core.exec_models import (JobExecutor, ClusteredExecutor,
                                    WorkerPoolExecutor)
from repro.core.autoscaler import proportional_replicas

__all__ = ["Task", "Workflow", "montage", "montage_small", "ClusterSim",
           "HyperflowEngine", "RunReport", "JobExecutor", "ClusteredExecutor",
           "WorkerPoolExecutor", "proportional_replicas"]
