"""HyperFlow-style workflow model of computation.

A workflow is a DAG of typed tasks. The engine fires tasks whose dependencies
are satisfied ("signals" in HyperFlow terms) and reacts to completions. This
mirrors the paper's Section 3.5: the engine is execution-model-agnostic — it
hands ready tasks to an *executor* (job-based, clustered, or worker-pools).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional


@dataclasses.dataclass
class Task:
    id: int
    type: str
    duration: float                    # seconds of compute on `cpu` cores
    cpu: float = 1.0                   # requested cores
    mem: float = 1024.0                # requested MB
    deps: List[int] = dataclasses.field(default_factory=list)
    # runtime
    children: List[int] = dataclasses.field(default_factory=list)
    unmet: int = 0
    submitted_at: Optional[float] = None
    started_at: Optional[float] = None
    finished_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None


class Workflow:
    def __init__(self, name: str = "workflow"):
        self.name = name
        self.tasks: Dict[int, Task] = {}
        self._next_id = 0

    def add(self, type: str, duration: float, deps: Iterable[int] = (),
            cpu: float = 1.0, mem: float = 1024.0) -> int:
        tid = self._next_id
        self._next_id += 1
        t = Task(tid, type, duration, cpu, mem, list(deps))
        t.unmet = len(t.deps)
        self.tasks[tid] = t
        for d in t.deps:
            self.tasks[d].children.append(tid)
        return tid

    def __len__(self) -> int:
        return len(self.tasks)

    def roots(self) -> List[Task]:
        return [t for t in self.tasks.values() if t.unmet == 0]

    def complete(self, tid: int, now: float) -> List[Task]:
        """Mark task done; return newly-ready tasks."""
        t = self.tasks[tid]
        assert t.finished_at is None, f"task {tid} completed twice"
        t.finished_at = now
        ready = []
        for c in t.children:
            ct = self.tasks[c]
            ct.unmet -= 1
            if ct.unmet == 0:
                ready.append(ct)
        return ready

    def all_done(self) -> bool:
        return all(t.done for t in self.tasks.values())

    def n_done(self) -> int:
        return sum(1 for t in self.tasks.values() if t.done)

    def task_types(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for t in self.tasks.values():
            out[t.type] = out.get(t.type, 0) + 1
        return out

    def critical_path(self) -> float:
        """Longest dependency chain by duration (lower bound on makespan)."""
        memo: Dict[int, float] = {}

        order = sorted(self.tasks)          # ids are topologically ordered
        for tid in order:
            t = self.tasks[tid]
            base = max((memo[d] for d in t.deps), default=0.0)
            memo[tid] = base + t.duration
        return max(memo.values()) if memo else 0.0

    def total_work(self) -> float:
        """Total core-seconds."""
        return sum(t.duration * t.cpu for t in self.tasks.values())
