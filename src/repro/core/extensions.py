"""The paper's §5 future-work agenda, implemented (beyond-paper):

1. **Vertical pod auto-scaling (VPA)** — the paper: "we plan investigating
   the impact of vertical Pod auto-scaling". Scientific tasks are routinely
   over-provisioned (CPU request ≫ true utilization); the VPA observes
   per-task-type utilization and right-sizes worker requests, letting the
   bin-packer place more workers per node.

2. **Multi-cluster (multi-cloud) worker pools** — the paper: "evaluating the
   execution models in a multi-cloud setting involving multiple Kubernetes
   clusters". A federated executor runs one worker-pool substack per
   cluster behind a shared global queue; tasks carry a data-home cluster
   and pay a transfer penalty when executed remotely. The proportional
   autoscaler splits each cluster's quota among its local pools.
"""
from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence

from repro.core.cluster import ClusterSim, Node
from repro.core.exec_models import WorkerPoolExecutor, _Pool
from repro.core.workflow import Task


class VerticalAutoscaler:
    """Right-sizes per-type CPU requests from observed utilization.

    Kubernetes VPA semantics, simplified to the simulator: after
    ``min_samples`` completions of a task type, the recommended request is
    p_max(observed utilization) x (1 + margin), bounded below by
    ``min_request``. Workers created after the recommendation use it.
    """

    def __init__(self, margin: float = 0.15, min_samples: int = 5,
                 min_request: float = 0.1):
        self.margin = margin
        self.min_samples = min_samples
        self.min_request = min_request
        self._obs: Dict[str, List[float]] = collections.defaultdict(list)

    def observe(self, task_type: str, cpu_used: float):
        self._obs[task_type].append(cpu_used)

    def recommend(self, task_type: str, current: float) -> float:
        obs = self._obs.get(task_type, ())
        if len(obs) < self.min_samples:
            return current
        rec = max(obs) * (1.0 + self.margin)
        return max(self.min_request, min(current, rec))


class VerticalWorkerPoolExecutor(WorkerPoolExecutor):
    """Worker pools + VPA: new workers adopt the right-sized request."""

    def __init__(self, *args, vpa: Optional[VerticalAutoscaler] = None, **kw):
        super().__init__(*args, **kw)
        self.vpa = vpa or VerticalAutoscaler()

    def _run_on(self, pool, pod, task):
        used = getattr(task, "cpu_used", None)
        if used is not None:
            self.vpa.observe(task.type, used)
        super()._run_on(pool, pod, task)

    def _tick(self):
        for pool in self.pools.values():
            pool.cpu = self.vpa.recommend(pool.type, pool.cpu)
        super()._tick()


class FederatedWorkerPoolExecutor:
    """Worker pools across multiple clusters with data locality.

    Each cluster gets its own WorkerPoolExecutor over its own ClusterSim...
    simplified here to ONE simulator whose nodes are partitioned into named
    clusters (single global clock): each cluster runs an independent pool
    substack; a router assigns every task to its data-home cluster unless
    the home backlog exceeds ``steal_threshold`` x the remote backlog, in
    which case the task is "stolen" and pays ``transfer_penalty`` seconds
    (input staging across clouds).
    """

    def __init__(self, clusters: Dict[str, Sequence[int]],
                 pooled_types: Optional[Sequence[str]] = None,
                 transfer_penalty: float = 5.0,
                 steal_threshold: float = 2.0):
        self.cluster_nodes = {k: set(v) for k, v in clusters.items()}
        self.transfer_penalty = transfer_penalty
        self.steal_threshold = steal_threshold
        self.subs: Dict[str, WorkerPoolExecutor] = {
            name: WorkerPoolExecutor(pooled_types=pooled_types)
            for name in clusters
        }
        self.stolen = 0
        self.engine = None
        self.sim = None

    def bind(self, engine, sim: ClusterSim):
        self.engine, self.sim = engine, sim
        for name, sub in self.subs.items():
            view = _ClusterView(sim, self.cluster_nodes[name])
            sub.bind(engine, view)

    def _backlog(self, name: str) -> int:
        return sum(int(p.demand()) for p in self.subs[name].pools.values())

    def submit(self, task: Task):
        home = getattr(task, "data_home", None) or next(iter(self.subs))
        target = home
        others = [n for n in self.subs if n != home]
        if others:
            best = min(others, key=self._backlog)
            if self._backlog(home) > self.steal_threshold * (
                    self._backlog(best) + 1):
                target = best
        if target != home:
            self.stolen += 1
            task.duration += self.transfer_penalty      # input staging
        self.subs[target].submit(task)

    def shutdown(self):
        for sub in self.subs.values():
            sub.shutdown()


class _ClusterView:
    """A ClusterSim facade restricted to a subset of nodes — each federated
    substack schedules only onto its own cloud."""

    def __init__(self, sim: ClusterSim, node_ids):
        self._sim = sim
        self._nodes = [n for n in sim.nodes if n.id in node_ids]

    def __getattr__(self, name):
        return getattr(self._sim, name)

    @property
    def nodes(self) -> List[Node]:
        return self._nodes

    def capacity_cores(self) -> float:
        return sum(n.cpu for n in self._nodes)

    def free_cores(self) -> float:
        return sum(n.cpu - n.used_cpu for n in self._nodes)

    def submit_pod(self, name, cpu, mem, on_started):
        pod = self._sim.submit_pod(name, cpu, mem, on_started)
        pod.allowed_nodes = {n.id for n in self._nodes}
        return pod
