"""Parametric Montage workflow generator.

Reproduces the structure of the paper's test workload: a large Montage run
(~16k tasks) with three parallel stages — mProject, mDiffFit (most numerous,
~2 s tasks), mBackground — joined by sequential aggregation steps. mProject
and mDiffFit intertwine (a mDiffFit fires as soon as its two overlapping
mProject tiles are done), which is exactly the proportional-allocation
stressor from §3.4 of the paper.

Task durations are drawn from lognormal distributions whose means were
calibrated once so that the *clustered job model* reproduces the paper's
≈1700 s makespan on the paper's 17×4-core cluster (see EXPERIMENTS.md
§Calibration); the job/clustered/worker-pool *relative* results are emergent.
"""
from __future__ import annotations

import random
from typing import Dict

from repro.core.workflow import Workflow

# Mean durations (seconds). mDiffFit mean matches the paper's stated 2 s.
DEFAULT_DURATIONS: Dict[str, float] = {
    "mProject": 20.0,
    "mDiffFit": 2.0,
    "mConcatFit": 20.0,
    "mBgModel": 40.0,
    "mBackground": 3.0,
    "mImgtbl": 10.0,
    "mAdd": 60.0,
    "mShrink": 15.0,
    "mJPEG": 10.0,
}

# True CPU utilization per type (tasks are over-provisioned at request=1.0;
# the VPA extension right-sizes requests toward these — core/extensions.py)
CPU_UTIL: Dict[str, float] = {
    "mProject": 0.85, "mDiffFit": 0.45, "mBackground": 0.5,
    "mConcatFit": 0.7, "mBgModel": 0.9, "mImgtbl": 0.6,
    "mAdd": 0.9, "mShrink": 0.7, "mJPEG": 0.6,
}

# Memory requests (MB) per task type — Montage tasks are memory-light.
MEM: Dict[str, float] = {t: 512.0 for t in DEFAULT_DURATIONS}
MEM.update({"mAdd": 2048.0, "mBgModel": 1024.0})


def montage(n_tiles: int = 3200, diff_ratio: float = 2.9375, seed: int = 7,
            durations: Dict[str, float] | None = None,
            sigma: float = 0.25) -> Workflow:
    """Build a Montage DAG.

    n_tiles=3200 with the default ratio yields ~16.2k tasks (the paper's
    "16k-task" workload): 3200 mProject + 9400 mDiffFit + 3200 mBackground
    + 6 sequential tasks.
    """
    rng = random.Random(seed)
    dur = dict(DEFAULT_DURATIONS)
    if durations:
        dur.update(durations)

    def d(t: str) -> float:
        return max(0.2, rng.lognormvariate(0, sigma) * dur[t])

    wf = Workflow(f"montage-{n_tiles}")

    def annotate(tid, tile=None):
        t = wf.tasks[tid]
        t.cpu_used = CPU_UTIL.get(t.type, 0.8) * t.cpu
        # data locality: tiles in the first half live in cluster "A"
        if tile is not None:
            t.data_home = "A" if tile < n_tiles // 2 else "B"
        return tid

    proj = [annotate(wf.add("mProject", d("mProject"), mem=MEM["mProject"]),
                     i) for i in range(n_tiles)]

    # mDiffFit joins *adjacent* tile pairs (real Montage overlaps neighbours
    # on a sky grid): horizontal, vertical and diagonal neighbours. This
    # locality makes mDiffFit readiness track mProject progress — the
    # intertwined-stage behaviour the paper evaluates.
    n_diff = int(n_tiles * diff_ratio)
    side = max(2, int(n_tiles ** 0.5))
    pairs = []
    for i in range(n_tiles):
        for off in (1, side, side + 1):
            j = i + off
            if j < n_tiles and (off != 1 or (i + 1) % side):
                pairs.append((i, j))
    while len(pairs) < n_diff:                    # wrap for high ratios
        pairs.append(pairs[len(pairs) % max(1, n_tiles)])
    diffs = []
    for a, b in pairs[:n_diff]:
        diffs.append(annotate(wf.add("mDiffFit", d("mDiffFit"),
                                     deps=(proj[a], proj[b]),
                                     mem=MEM["mDiffFit"]), a))

    concat = annotate(wf.add("mConcatFit", d("mConcatFit"), deps=diffs,
                    mem=MEM["mConcatFit"]))
    bgmodel = wf.add("mBgModel", d("mBgModel"), deps=(concat,),
                     mem=MEM["mBgModel"])
    bgs = [annotate(wf.add("mBackground", d("mBackground"),
                           deps=(bgmodel, p), mem=MEM["mBackground"]), i)
           for i, p in enumerate(proj)]
    imgtbl = wf.add("mImgtbl", d("mImgtbl"), deps=bgs, mem=MEM["mImgtbl"])
    madd = wf.add("mAdd", d("mAdd"), deps=(imgtbl,), mem=MEM["mAdd"])
    shrink = wf.add("mShrink", d("mShrink"), deps=(madd,), mem=MEM["mShrink"])
    wf.add("mJPEG", d("mJPEG"), deps=(shrink,), mem=MEM["mJPEG"])
    return wf


def montage_small(n_tiles: int = 400, seed: int = 7) -> Workflow:
    """The smaller instance the paper used for the (collapsing) job model."""
    return montage(n_tiles=n_tiles, seed=seed)
