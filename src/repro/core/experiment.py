"""Canonical experiment configuration reproducing the paper's §4 setup.

Cluster: 17 worker nodes x 4 cores (68 cores), the paper's OpenStack/K8s
deployment at Cyfronet. Workload: 16k-task Montage (3200 tiles). Task-mean
durations were calibrated ONCE against two anchors from the paper —
(a) best job-based (clustered) makespan ≈ 1700 s, (b) mDiffFit mean = 2 s —
with the scheduler back-off cap (130 s) shared by ALL execution models.
Everything else (job-model collapse, worker-pool ≈ 1420 s, ≈20 % improvement,
utilization traces) is EMERGENT, not fitted. See EXPERIMENTS.md §Calibration.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.core.cluster import ClusterSim
from repro.core.engine import HyperflowEngine, RunReport
from repro.core.exec_models import (ClusteredExecutor, JobExecutor,
                                    WorkerPoolExecutor)
from repro.core.montage import montage

N_NODES = 17
NODE_CPU = 4.0
BACKOFF_INITIAL = 5.0
BACKOFF_MAX = 130.0
N_TILES = 3200                      # -> 15,806 tasks ("16k")
SIGMA = 0.2

PAPER_DURATIONS: Dict[str, float] = {
    "mProject": 17.0, "mDiffFit": 2.0, "mBackground": 2.5,
    "mConcatFit": 12.0, "mBgModel": 25.0, "mImgtbl": 6.0,
    "mAdd": 40.0, "mShrink": 10.0, "mJPEG": 6.0,
}

# the paper's agglomeration config (§3.5 example, extended to mBackground)
CLUSTERING_RULES: Dict[str, dict] = {
    "mProject": {"size": 5, "timeoutMs": 3000},
    "mDiffFit": {"size": 20, "timeoutMs": 3000},
    "mBackground": {"size": 20, "timeoutMs": 3000},
}

POOLED_TYPES = ("mProject", "mDiffFit", "mBackground")   # hybrid model, §4.4


def make_sim(seed: int = 7, n_nodes: int = N_NODES) -> ClusterSim:
    return ClusterSim(n_nodes=n_nodes, node_cpu=NODE_CPU,
                      backoff_initial=BACKOFF_INITIAL,
                      backoff_max=BACKOFF_MAX, seed=seed)


def make_workflow(seed: int = 7, n_tiles: int = N_TILES):
    return montage(n_tiles=n_tiles, durations=PAPER_DURATIONS, seed=seed,
                   sigma=SIGMA)


def make_executor(model: str, rules: Optional[dict] = None,
                  pooled: Optional[Sequence[str]] = POOLED_TYPES):
    if model == "job":
        return JobExecutor()
    if model == "clustered":
        return ClusteredExecutor(rules or CLUSTERING_RULES)
    if model == "worker_pools":
        return WorkerPoolExecutor(pooled_types=pooled)
    raise ValueError(model)


def run_model(model: str, seed: int = 7, n_tiles: int = N_TILES,
              until: Optional[float] = None, **kw):
    wf = make_workflow(seed, n_tiles)
    sim = make_sim(seed)
    eng = HyperflowEngine(wf, make_executor(model, **kw), sim)
    rep = eng.run(until=until)
    return rep, wf, sim


def utilization_windows(sim: ClusterSim, window: float = 25.0):
    """Windowed busy-core fractions (the paper's utilization subplots)."""
    out = {}
    trace = sim.busy_cores_trace
    for (t0, v), (t1, _) in zip(trace, trace[1:]):
        a, b = t0, t1
        while a < b:
            w = int(a // window)
            e = min(b, (w + 1) * window)
            out[w] = out.get(w, 0.0) + v * (e - a)
            a = e
    cap = sim.capacity_cores() * window
    return [(w * window, out.get(w, 0.0) / cap)
            for w in range(int(max(out) if out else 0) + 1)]
