"""Step builders: train_step / prefill_step / decode_step for any
(architecture x shape x mesh) cell, with shardings resolved from the
per-arch policy. Used by the trainer, the server, and the dry-run.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models.model import Model, build_model, cache_template
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.policies import default_fsdp, policy_for
from repro.parallel.sharding import ShardingPolicy, fsdp_param_spec, use_policy

Pytree = Any


@dataclasses.dataclass
class Cell:
    """One lowered unit of work: a step fn + abstract inputs + shardings."""
    arch: ArchConfig
    shape: ShapeConfig
    policy: ShardingPolicy
    step_fn: Any                    # python callable (to be jitted)
    in_abstract: Tuple              # pytree of ShapeDtypeStruct
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()

    def jitted(self):
        return jax.jit(self.step_fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=self.donate_argnums)

    def lower(self):
        return self.jitted().lower(*self.in_abstract)


def _named(policy: ShardingPolicy, spec: P) -> NamedSharding:
    return NamedSharding(policy.mesh, spec)


def _default_context_parallel(arch, shape, tp, overrides):
    """Context-parallel attention by default when heads can't use the model
    axis (K % tp and G % tp both nonzero): shard the q sequence inside flash
    (KV replicated) — removes the tp-fold replicated attention compute.
    (EXPERIMENTS §Perf, beyond-paper.)"""
    K = arch.num_kv_heads
    G = max(1, arch.num_heads // K)
    if (shape.kind in ("train", "prefill")
            and (overrides or {}).get("attn_q_seq") is None
            and arch.family in ("dense", "vlm", "audio")   # MoE: the model
            # axis belongs to EP — seq-sharded tokens entering the dispatch
            # einsum cause reshard storms (measured 5x regression)
            and K % tp and G % tp and shape.seq_len % tp == 0):
        return {**(overrides or {}), "attn_q_seq": "model"}
    return overrides


def _param_shardings(model: Model, policy: ShardingPolicy):
    abs_p, axes = model.abstract()
    specs = jax.tree.map(
        lambda leaf, ax: fsdp_param_spec(policy, ax, leaf.shape),
        abs_p, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    shardings = jax.tree.map(lambda s: _named(policy, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return abs_p, specs, shardings


def _batch_shardings(policy: ShardingPolicy, specs_axes: Dict[str, Any]):
    abstract, shardings = {}, {}
    for name, (spec, axes) in specs_axes.items():
        if name in ("cache", "pos"):
            continue
        abstract[name] = spec
        shardings[name] = _named(policy, policy.spec(*axes))
    return abstract, shardings


def _cache_shardings(policy: ShardingPolicy, spec, axes):
    shardings = jax.tree.map(
        lambda s, ax: _named(policy, policy.spec(*ax)),
        spec, axes, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    return shardings


# --------------------------------------------------------------- train -----

def build_train_cell(arch: ArchConfig, shape: ShapeConfig, mesh, *,
                     opt: Optional[AdamWConfig] = None,
                     fsdp: Optional[bool] = None,
                     overrides=None, seq_shard: bool = False,
                     remat: Optional[bool] = None,
                     accum_dtype=jnp.float32) -> Cell:
    assert shape.kind == "train"
    if remat is not None and remat != arch.remat:
        arch = dataclasses.replace(arch, remat=remat)
    model = build_model(arch)
    opt = opt or AdamWConfig(moment_dtype=arch.opt_dtype)
    tp = mesh.shape.get("model", 1)
    if fsdp is None:
        fsdp = default_fsdp(arch, "train", tp)
    overrides = _default_context_parallel(arch, shape, tp, overrides)
    policy = policy_for(arch, mesh, fsdp=fsdp, overrides=overrides,
                        seq_shard=seq_shard,
                        global_batch=shape.microbatch or shape.global_batch)

    abs_p, p_specs, p_shard = _param_shardings(model, policy)
    mdt = jnp.dtype(opt.moment_dtype)
    abs_m = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, mdt), abs_p)
    abs_state = {"params": abs_p, "m": abs_m, "v": abs_m,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
    state_shard = {"params": p_shard, "m": p_shard, "v": p_shard,
                   "step": _named(policy, P())}

    specs_axes = model.input_specs(shape, dtype=arch.adtype)
    abs_batch, batch_shard = _batch_shardings(policy, specs_axes)
    micro = shape.microbatch and shape.microbatch < shape.global_batch

    def train_step(state, batch):
        with use_policy(policy):
            params = state["params"]

            def loss_fn(p, b):
                return model.loss(p, b)

            if micro:
                n_micro = shape.global_batch // shape.microbatch
                # fp32 accumulators SHARDED like the params: the per-micro
                # cross-data grad combine lowers to reduce-scatter onto the
                # FSDP shard instead of a full all-reduce (16x less volume),
                # and the accumulator itself stays sharded in HBM
                acc0 = jax.tree.map(
                    lambda x, s: jax.lax.with_sharding_constraint(
                        jnp.zeros(x.shape, accum_dtype), s),
                    params, p_shard)

                def micro_body(carry, mb):
                    gacc, lsum = carry
                    (loss, _), grads = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    gacc = jax.tree.map(
                        lambda a, g, s: jax.lax.with_sharding_constraint(
                            a + g.astype(accum_dtype), s),
                        gacc, grads, p_shard)
                    return (gacc, lsum + loss), None

                (gacc, lsum), _ = jax.lax.scan(
                    micro_body, (acc0, jnp.float32(0)), batch)
                grads = jax.tree.map(lambda g: g / n_micro, gacc)
                loss = lsum / n_micro
            else:
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)

            new_p, new_opt, stats = adamw_update(
                params, grads, {"m": state["m"], "v": state["v"],
                                "step": state["step"]}, opt)
            new_state = {"params": new_p, "m": new_opt["m"],
                         "v": new_opt["v"], "step": new_opt["step"]}
            metrics = {"loss": loss, **stats}
            return new_state, metrics

    metrics_shard = {"loss": _named(policy, P()),
                     "grad_norm": _named(policy, P()),
                     "lr": _named(policy, P())}
    return Cell(arch, shape, policy, train_step,
                (abs_state, abs_batch),
                (state_shard, batch_shard),
                (state_shard, metrics_shard),
                donate_argnums=(0,))


def init_train_state(model: Model, rng, opt: AdamWConfig):
    params = model.init(rng)
    o = adamw_init(params, opt)
    return {"params": params, "m": o["m"], "v": o["v"], "step": o["step"]}


# --------------------------------------------------------------- serve -----

def build_serve_cell(arch: ArchConfig, shape: ShapeConfig, mesh, *,
                     fsdp: Optional[bool] = None, overrides=None,
                     seq_shard: bool = False, cache_dtype=None) -> Cell:
    assert shape.kind in ("prefill", "decode")
    model = build_model(arch)
    tp = mesh.shape.get("model", 1)
    if fsdp is None:
        fsdp = default_fsdp(arch, shape.kind, tp)
    overrides = _default_context_parallel(arch, shape, tp, overrides)
    policy = policy_for(arch, mesh, fsdp=fsdp, overrides=overrides,
                        seq_shard=seq_shard, global_batch=shape.global_batch)
    # Default: decode of dense-family archs whose heads can't use the model
    # axis gets a sequence-sharded KV cache (sequence-parallel flash-decode,
    # EXPERIMENTS §Perf Cell A). Ring caches (SWA) keep the plain path.
    if (shape.kind == "decode" and (overrides or {}).get("cache_seq") is None
            and arch.family in ("dense", "moe", "vlm", "audio")
            and not arch.sliding_window
            and policy.rules.get("kv_heads") is None
            and policy.rules.get("qgroup") is None
            and shape.seq_len % tp == 0):
        policy = policy_for(arch, mesh, fsdp=fsdp,
                            overrides={**(overrides or {}),
                                       "cache_seq": "model"},
                            seq_shard=seq_shard,
                            global_batch=shape.global_batch)

    abs_p, _, p_shard = _param_shardings(model, policy)
    specs_axes = model.input_specs(shape, dtype=arch.adtype,
                                   cache_dtype=cache_dtype)
    abs_batch, batch_shard = _batch_shardings(policy, specs_axes)
    cache_spec, cache_axes = specs_axes["cache"]
    cache_shard = _cache_shardings(policy, cache_spec, cache_axes)

    logits_shard = _named(policy, policy.spec("batch", None, "vocab"))

    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            with use_policy(policy):
                return model.prefill(params, batch, cache)

        return Cell(arch, shape, policy, prefill_step,
                    (abs_p, abs_batch, cache_spec),
                    (p_shard, batch_shard, cache_shard),
                    (logits_shard, cache_shard),
                    donate_argnums=(2,))

    def decode_step(params, tokens, cache, pos):
        with use_policy(policy):
            return model.decode_step(params, tokens, cache, pos)

    tok_shard = batch_shard["tokens"]
    pos_shard = _named(policy, P())
    return Cell(arch, shape, policy, decode_step,
                (abs_p, abs_batch["tokens"], cache_spec,
                 specs_axes["pos"][0]),
                (p_shard, tok_shard, cache_shard, pos_shard),
                (logits_shard, cache_shard),
                donate_argnums=(2,))


def build_cell(arch: ArchConfig, shape: ShapeConfig, mesh, **kw) -> Cell:
    if shape.kind == "train":
        return build_train_cell(arch, shape, mesh, **kw)
    return build_serve_cell(arch, shape, mesh, **kw)
