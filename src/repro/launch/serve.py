"""Batched serving driver: prefill + decode worker pools per architecture.

Serves synthetic request batches with the worker-pool execution model:
persistent compiled prefill/decode executables per arch, fed from request
queues; reports tokens/s and per-phase latency. Runs reduced configs for
real on this host; the same step builders lower to the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --requests 8 --prompt-len 24 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    B, P, G = args.batch, args.prompt_len, args.gen
    max_len = P + G

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    rng = jax.random.PRNGKey(args.seed + 1)
    n_batches = (args.requests + B - 1) // B
    total_tokens = 0
    t_compile = None
    t0 = time.perf_counter()
    for bi in range(n_batches):
        rng, k = jax.random.split(rng)
        batch = {"tokens": jax.random.randint(k, (B, P), 0, cfg.vocab_size)}
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                k, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.random.normal(
                k, (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        cache = model.init_cache(B, max_len, dtype=jnp.float32)
        tp0 = time.perf_counter()
        logits, cache = prefill(params, batch, cache)
        jax.block_until_ready(logits)
        tp1 = time.perf_counter()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs = [tok]
        for i in range(G - 1):
            logits, cache = decode(params, tok, cache, jnp.int32(P + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(tok)
        jax.block_until_ready(tok)
        tp2 = time.perf_counter()
        if bi == 0:
            t_compile = tp2 - tp0            # first batch includes compiles
        total_tokens += B * G
        print(f"batch {bi}: prefill={1e3*(tp1-tp0):.1f}ms "
              f"decode={1e3*(tp2-tp1):.1f}ms "
              f"({B*G/(tp2-tp0):.1f} tok/s)")
    dt = time.perf_counter() - t0
    print(f"served {total_tokens} tokens in {dt:.2f}s "
          f"(first-batch incl. compile: {t_compile:.2f}s) — "
          f"steady-state pools amortize that compile across the fleet")
    return 0


if __name__ == "__main__":
    main()
