"""End-to-end training driver with checkpoint/restart fault tolerance.

On this CPU container it trains reduced or small full configs for real
(losses decrease); on TPU the same code path scales to the production mesh
via --mesh. Examples:

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 60 --ckpt /tmp/ck --inject-fault-at 25
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import make_batch_fn
from repro.engine.fault_tolerance import FaultInjector, TrainSupervisor
from repro.launch.steps import build_train_cell, init_train_state
from repro.models import build_model
from repro.optim import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-fault-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", args.seq, args.batch, "train",
                        microbatch=args.microbatch)
    model = build_model(cfg)
    opt = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps,
                      moment_dtype=cfg.opt_dtype)
    batch_fn = make_batch_fn(cfg, shape, args.seed)

    from repro.optim import adamw_update

    @jax.jit
    def train_step(state, batch):
        (loss, m), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state["params"], batch)
        new_p, new_o, stats = adamw_update(
            state["params"], grads,
            {"m": state["m"], "v": state["v"], "step": state["step"]}, opt)
        return {"params": new_p, **new_o}, {"loss": loss, **stats}

    def step_fn(state, i):
        return train_step(state, batch_fn(i))

    def make_state():
        return init_train_state(model, jax.random.PRNGKey(args.seed), opt)

    t0 = time.time()
    if args.ckpt:
        sup = TrainSupervisor(
            args.ckpt, make_state, step_fn, every=args.ckpt_every,
            injector=FaultInjector(tuple(args.inject_fault_at))
            if args.inject_fault_at else None)
        state, log, restarts = sup.run(args.steps)
        for s, m in log:
            if s % args.log_every == 0 or s == args.steps:
                print(f"step {s}: loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f}")
        print(f"done: {args.steps} steps, {restarts} restart(s), "
              f"{time.time()-t0:.1f}s")
    else:
        state = make_state()
        for i in range(args.steps):
            state, m = step_fn(state, i)
            if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
                print(f"step {i+1}: loss={float(m['loss']):.4f} "
                      f"gnorm={float(m['grad_norm']):.3f}")
        print(f"done: {args.steps} steps, {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    main()
