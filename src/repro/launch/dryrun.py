import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: AOT-lower + compile every (architecture x input shape)
cell on the production meshes and extract roofline inputs.

MUST be run as its own process (`python -m repro.launch.dryrun ...`): the
XLA_FLAGS line above executes before any jax import so the host is carved
into 512 placeholder devices. Never set this in conftest/pyproject — tests
and benches see 1 device.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all            # every cell, subprocess each
"""
import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch_name: str, shape_name: str, mesh_name: str,
             fsdp: str = "auto", microbatch: int = -1, seq_shard: bool = False,
             remat: str = "auto", out_dir: Path = ART, tag: str = "",
             overrides=None, cache_dtype: str = "", accum_dtype: str = "",
             verbose: bool = True) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import ARCHS, SHAPES, shape_applicable
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell
    from repro.roofline.analysis import analyze_compiled

    arch = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
                "skipped": why}
    if shape.kind == "train" and microbatch != 0:
        shape = shape.with_microbatch(
            32 if microbatch < 0 else microbatch)

    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.devices.size
    kw = {}
    if fsdp != "auto":
        kw["fsdp"] = fsdp == "on"
    if remat != "auto" and shape.kind == "train":
        kw["remat"] = remat == "on"
    cache_bytes = 2
    if cache_dtype and shape.kind in ("prefill", "decode"):
        kw["cache_dtype"] = jnp.dtype(cache_dtype)
        cache_bytes = kw["cache_dtype"].itemsize
    if accum_dtype and shape.kind == "train":
        kw["accum_dtype"] = jnp.dtype(accum_dtype)
    if overrides:
        kw["overrides"] = {k: (None if v in ("none", "None") else v)
                           for k, v in overrides.items()}
    cell = build_cell(arch, shape, mesh, seq_shard=seq_shard, **kw)

    t0 = time.time()
    with mesh:
        lowered = cell.lower()
        compiled = lowered.compile()
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if verbose:
        print(f"[dryrun] {arch_name} x {shape_name} x {mesh_name}: "
              f"compiled in {dt:.1f}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={cost.get('flops', 0):.3e} "
              f"bytes={cost.get('bytes accessed', 0):.3e}")
    rep = analyze_compiled(compiled, arch=arch, shape=shape,
                           mesh_name=mesh_name, chips=chips,
                           compile_seconds=dt, policy=cell.policy,
                           cache_bytes=cache_bytes)
    d = rep.to_json()
    d["fsdp"] = kw.get("fsdp", "auto")
    d["microbatch"] = shape.microbatch
    d["seq_shard"] = seq_shard
    d["tag"] = tag
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = out_dir / f"{arch_name}_{shape_name}_{mesh_name}{suffix}.json"
    path.write_text(json.dumps(d, indent=1))
    if verbose:
        print(f"  terms: compute={rep.compute_term*1e3:.2f}ms "
              f"memory={rep.memory_term*1e3:.2f}ms "
              f"collective={rep.collective_term_ring*1e3:.2f}ms "
              f"dominant={rep.dominant} "
              f"roofline_fraction={rep.roofline_fraction:.3f}")
        print(f"  -> {path}")
    return d


def run_all(meshes=("pod", "multipod"), jobs_filter=None, out_dir=ART):
    """Drive every cell in a fresh subprocess (isolates XLA state/memory)."""
    from repro.configs import ARCHS, SHAPES, shape_applicable
    results, failures = [], []
    cells = [(a, s, m) for a in sorted(ARCHS) for s in SHAPES
             for m in meshes]
    for a, s, m in cells:
        if jobs_filter and not jobs_filter((a, s, m)):
            continue
        ok, why = shape_applicable(ARCHS[a], SHAPES[s])
        out = out_dir / f"{a}_{s}_{m}.json"
        if not ok:
            out_dir.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(
                {"arch": a, "shape": s, "mesh": m, "skipped": why}, indent=1))
            print(f"[skip] {a} x {s} x {m}: {why}")
            continue
        if out.exists():
            d = json.loads(out.read_text())
            if "error" not in d:
                print(f"[cached] {a} x {s} x {m}")
                results.append(d)
                continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--mesh", m]
        print(f"[run] {' '.join(cmd[3:])}", flush=True)
        r = subprocess.run(cmd, capture_output=True, text=True)
        if r.returncode != 0:
            failures.append((a, s, m, r.stdout[-2000:] + r.stderr[-2000:]))
            out_dir.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(
                {"arch": a, "shape": s, "mesh": m,
                 "error": r.stderr[-2000:]}, indent=1))
            print(f"  FAILED:\n{r.stderr[-1500:]}")
        else:
            print(r.stdout[-500:])
    if failures:
        print(f"{len(failures)} FAILURES:")
        for a, s, m, _ in failures:
            print(f"  {a} x {s} x {m}")
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--fsdp", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--remat", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--microbatch", type=int, default=-1,
                    help="-1 auto, 0 off, N explicit")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="logical=meshaxis sharding-rule override, e.g. "
                         "cache_seq=model or experts=none (repeatable)")
    ap.add_argument("--cache-dtype", default="",
                    help="KV-cache dtype for serve cells (e.g. int8)")
    ap.add_argument("--accum-dtype", default="",
                    help="grad-accumulator dtype for train cells "
                         "(e.g. bfloat16)")
    ap.add_argument("--tag", default="", help="suffix for artifact file")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all())
    assert args.arch and args.shape, "--arch and --shape required"
    overrides = dict(kv.split("=", 1) for kv in args.override)
    run_cell(args.arch, args.shape, args.mesh, fsdp=args.fsdp,
             microbatch=args.microbatch, seq_shard=args.seq_shard,
             remat=args.remat, tag=args.tag, overrides=overrides or None,
             cache_dtype=args.cache_dtype, accum_dtype=args.accum_dtype)


if __name__ == "__main__":
    main()
