"""Production mesh construction.

A pod is a 16x16 slice of TPU v5e (256 chips): axes (data, model).
Multi-pod adds a leading "pod" axis: (2, 16, 16) = 512 chips; the batch
shards over ("pod", "data") — pure data parallelism across pods, so the only
cross-pod (DCI) traffic is the gradient all-reduce.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...],
              devices: Optional[list] = None):
    n = 1
    for s in shape:
        n *= s
    devices = devices if devices is not None else jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before importing jax (dry-run only)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(max_devices: int = 8):
    """Small CPU mesh for tests: (data=min(n,2), model=rest)."""
    n = min(len(jax.devices()), max_devices)
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0 and cand <= n:
            model = cand
            break
    return make_mesh((n // model, model), ("data", "model"))
